"""Pipeline-schedule gradient tests: the ``lax.scan`` + ``ppermute``
pipelines of core/pipeline.py (GPipe and interleaved 1F1B) are
differentiable, and their loss/gradients match the unpipelined stacked
model to ≤1e-5 — including micro-batch counts that do not divide the
stage count, the m == s drain boundary, and the d2.t2.s2 composed mesh
through the full Strategy path.  Bubble/tick accounting is asserted
host-side.
"""
import pytest

from repro.core.pipeline import (bubble_fraction, gpipe_ticks,
                                 onefb_bubble_fraction, onefb_ticks)


# ----------------------------------------------------- bubble accounting
def test_gpipe_tick_and_bubble_accounting():
    # M micro-batches drain through S stages in M + S - 1 ticks
    assert gpipe_ticks(1, 4) == 4
    assert gpipe_ticks(4, 1) == 4
    assert gpipe_ticks(2, 3) == 4
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    # more micro-batches amortize the bubble monotonically
    fracs = [bubble_fraction(4, m) for m in (1, 2, 4, 8, 16)]
    assert fracs == sorted(fracs, reverse=True)
    # tick count times per-tick work bounds the ideal speedup
    assert gpipe_ticks(4, 16) == 19          # vs 64 sequential stage calls


def test_onefb_tick_and_bubble_accounting():
    # v virtual chunks per device: v*M chunk-micro units drain through S
    # devices in v*M + S - 1 ticks; each tick does 1/v of a stage's work
    assert onefb_ticks(4, 8, interleave=2) == 19
    assert onefb_ticks(4, 8, interleave=1) == 11
    assert onefb_bubble_fraction(4, 8, 2) == pytest.approx(3 / 19)
    # plain (v=1) 1F1B has the same bubble *fraction* as GPipe — the
    # schedule reorders work but idles the same ramp ticks; only
    # interleaving shrinks the bubble
    for s, m in ((2, 4), (4, 8), (4, 6)):
        assert onefb_bubble_fraction(s, m, 1) == \
            pytest.approx(bubble_fraction(s, m))
        for v in (2, 4):
            assert onefb_bubble_fraction(s, m, v) < bubble_fraction(s, m)
    # more chunks amortize monotonically
    fracs = [onefb_bubble_fraction(4, 8, v) for v in (1, 2, 4, 8)]
    assert fracs == sorted(fracs, reverse=True)


# --------------------------------------- pipeline grads vs stacked model
SCRIPT_GRADS = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.collectives import shard_map
from repro.core.pipeline import (bubble_fraction, gpipe_forward,
                                 gpipe_ticks, stacked_forward)
from repro.parallel import make_tiny_transformer

D_MODEL, FF = 8, 16
KEY = jax.random.PRNGKey(7)

def run_case(n_stages, n_micro, mb):
    params, model = make_tiny_transformer(n_stages, D_MODEL, FF,
                                          seed=n_stages)
    stage_fn = lambda sp, x: model.stage_fn(sp, x)
    x = jax.random.normal(KEY, (n_micro, mb, D_MODEL))
    tgt = jax.random.normal(jax.random.fold_in(KEY, 1),
                            (n_micro, mb, D_MODEL))

    # ---- reference: unpipelined stacked forward + MSE loss and grads
    def ref_loss(p):
        y = stacked_forward(stage_fn, p, x)
        return jnp.mean((y - tgt) ** 2)
    l_ref, g_ref = jax.value_and_grad(ref_loss)(params)

    # ---- pipelined: shard_map over the stage axis, loss on last stage
    mesh = Mesh(np.array(jax.devices()[:n_stages]), ("stage",))
    def body(stacked):
        sp = stacked            # [chunk=1 layers...] via stage sharding
        def loss_fn(pl):
            outs = gpipe_forward(
                lambda spp, xx: stage_fn(
                    jax.tree.map(lambda l: l[0], spp), xx), pl, x, "stage")
            l = jnp.mean((outs - tgt) ** 2)
            me = jax.lax.axis_index("stage")
            from repro.parallel.staged import tensor_reduce
            l = jnp.where(me == n_stages - 1, l, 0.0)
            return tensor_reduce("stage")(l)
        return jax.value_and_grad(loss_fn)(sp)
    spec = jax.tree.map(lambda _: P("stage"), params)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), spec), check_vma=False)
    l_pipe, g_pipe = jax.jit(fn)(params)

    ld = abs(float(l_ref) - float(l_pipe))
    gd = max(float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pipe)))
    assert ld <= 1e-5, (n_stages, n_micro, ld)
    assert gd <= 1e-5, (n_stages, n_micro, gd)
    # bubble accounting: the executed schedule ran exactly
    # gpipe_ticks(S, M) ticks, of which (S-1)/(M+S-1) are idle
    ticks = gpipe_ticks(n_stages, n_micro)
    assert ticks == n_micro + n_stages - 1
    assert 0 <= bubble_fraction(n_stages, n_micro) < 1
    print(f"GRAD-OK S={n_stages} M={n_micro} ticks={ticks} "
          f"bubble={bubble_fraction(n_stages, n_micro):.3f} "
          f"ld={ld:.1e} gd={gd:.1e}")

# divisible and NON-divisible micro counts, 2 and 4 stages
for n_stages, n_micro in ((2, 1), (2, 3), (2, 4), (4, 3), (4, 6)):
    run_case(n_stages, n_micro, mb=4)
print("PIPELINE-GRADS-OK")
"""


def test_gpipe_grads_match_stacked_model(multidevice):
    out = multidevice(SCRIPT_GRADS, 4)
    assert out.count("GRAD-OK") == 5
    assert "PIPELINE-GRADS-OK" in out


# ------------------------------------- 1F1B grads vs stacked, core level
SCRIPT_ONEFB = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.collectives import shard_map
from repro.core.pipeline import onefb_forward, stacked_forward
from repro.parallel.staged import tensor_reduce

KEY = jax.random.PRNGKey(3)

def run_case(n_stages, v, n_micro, layers_per_stage, mb=2):
    L = n_stages * layers_per_stage
    ks = jax.random.split(jax.random.fold_in(KEY, L*31 + v*7 + n_micro), 3)
    W = jax.random.normal(ks[0], (L, 8, 8)) * 0.3
    x = jax.random.normal(ks[1], (n_micro, mb, 8))
    tgt = jax.random.normal(ks[2], (n_micro, mb, 8))

    def stage_fn(sp, xx):
        for j in range(sp["W"].shape[0]):
            xx = jnp.tanh(xx @ sp["W"][j])
        return xx

    # reference: every layer its own "stage" of the stacked forward
    def ref_loss(p):
        y = stacked_forward(stage_fn, {"W": p["W"].reshape(L, 1, 8, 8)}, x)
        return jnp.mean((y - tgt) ** 2)
    l_ref, g_ref = jax.value_and_grad(ref_loss)({"W": W})

    # interleaved layout: device i holds chunks c = 0..v-1 with global
    # virtual stage c*S+i — permute rows device-major, chunk-major (the
    # same layout HybridEngine._permute_stacked applies at init)
    cl = layers_per_stage // v
    perm = np.concatenate([np.arange((c*n_stages + i)*cl,
                                     (c*n_stages + i + 1)*cl)
                           for i in range(n_stages) for c in range(v)])
    mesh = Mesh(np.array(jax.devices()[:n_stages]), ("stage",))
    def body(p):
        def loss_fn(pl):
            outs = onefb_forward(stage_fn, pl, x, "stage", interleave=v)
            l = jnp.mean((outs - tgt) ** 2)
            me = jax.lax.axis_index("stage")
            l = jnp.where(me == n_stages - 1, l, 0.0)
            return tensor_reduce("stage")(l)
        return jax.value_and_grad(loss_fn)(p)
    spec = {"W": P("stage")}
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), spec), check_vma=False)
    l_pipe, g_pipe = jax.jit(fn)({"W": W[perm]})
    g_pipe = np.asarray(g_pipe["W"])[np.argsort(perm)]

    ld = abs(float(l_ref) - float(l_pipe))
    gd = float(np.max(np.abs(np.asarray(g_ref["W"]) - g_pipe)))
    assert ld <= 1e-5, (n_stages, v, n_micro, ld)
    assert gd <= 1e-5, (n_stages, v, n_micro, gd)
    print(f"ONEFB-GRAD-OK S={n_stages} v={v} M={n_micro} "
          f"ld={ld:.1e} gd={gd:.1e}")

# interleaved + plain, divisible and NON-divisible micro counts, and the
# m == s drain boundary (1f1b needs m >= s)
for s, v, m in ((2, 2, 4), (2, 2, 8), (2, 1, 4), (4, 2, 6), (2, 2, 2),
                (2, 2, 3)):
    run_case(s, v, m, layers_per_stage=2)
print("ONEFB-GRADS-OK")
"""


def test_onefb_grads_match_stacked_model(multidevice):
    out = multidevice(SCRIPT_ONEFB, 4)
    assert out.count("ONEFB-GRAD-OK") == 6
    assert "ONEFB-GRADS-OK" in out


# ----------------------- schedules agree on the d2.t2.s2 composed mesh
SCRIPT_STRATEGY_1F1B = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.staged import make_tiny_transformer
from repro.train.strategy import Strategy, Trainer

params0, model = make_tiny_transformer(4, d_model=8, d_ff=16, seed=0)
rng = np.random.default_rng(0)
X = rng.standard_normal((16, 8)).astype(np.float32)
Y = rng.standard_normal((16, 8)).astype(np.float32)
batches = lambda t, w=0: {"x": jnp.asarray(X), "y": jnp.asarray(Y)}

def run(spec):
    p, hist, _ = Trainer(Strategy.parse(spec, lr=0.05)).fit(
        model, params0, batches, 3)
    return p, [e["loss"] for e in hist]

ref_p, ref_losses = run("bsp/ring/none@1")
for spec in ("bsp/ring/none@8:d2.t2.s2.m8",
             "bsp/ring/none@8:d2.t2.s2.m8.1f1b",
             "bsp/ring/none@8:d2.t2.s2.m8.1f1b.v1"):
    p, losses = run(spec)
    dl = max(abs(a - b) for a, b in zip(losses, ref_losses))
    dp = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ref_p)))
    assert dl <= 1e-5 and dp <= 1e-5, (spec, dl, dp)
    print(f"MESH-SCHED-OK {spec} dl={dl:.1e} dp={dp:.1e}")
print("STRATEGY-1F1B-OK")
"""


def test_1f1b_matches_gpipe_and_stacked_on_composed_mesh(multidevice):
    out = multidevice(SCRIPT_STRATEGY_1F1B, 8)
    assert out.count("MESH-SCHED-OK") == 3
    assert "STRATEGY-1F1B-OK" in out
