"""Config registry: all 10 assigned architectures with sane param counts."""
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, SKIPS, all_pairs, get_config

EXPECTED_PARAMS_B = {
    "tinyllama-1.1b": (0.9, 1.3),
    "kimi-k2-1t-a32b": (900, 1150),
    "whisper-large-v3": (1.2, 1.9),
    "deepseek-v2-lite-16b": (14, 18),
    "qwen2-vl-7b": (6.5, 9),
    "stablelm-1.6b": (1.4, 1.9),
    "recurrentgemma-9b": (7.5, 10.5),
    "rwkv6-7b": (6.5, 8.5),
    "command-r-35b": (28, 38),
    "llama3.2-3b": (2.8, 3.8),
}


def test_ten_archs_registered():
    assert len(ARCHS) == 10
    families = {c.family for c in ARCHS.values()}
    assert families == {"dense", "moe", "audio", "vlm", "hybrid", "ssm"}


def test_four_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_active_params_kimi():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count() / 1e9
    assert 25 <= active <= 60      # "a32b" ~= 32B activated


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_configs_small(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2 and r.d_model <= 512
    if r.moe:
        assert r.num_experts <= 4


def test_pairs_and_skips():
    pairs = list(all_pairs())
    assert len(pairs) == 39           # 40 minus whisper x long_500k
    assert ("whisper-large-v3", "long_500k") in SKIPS


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_model_dims_divisible_by_mesh(arch):
    """Every sharded trailing dim must divide the 16-way model axis."""
    cfg = get_config(arch)
    assert cfg.d_model % 16 == 0
    assert cfg.padded_vocab(16) % 16 == 0
    if cfg.num_heads:
        assert (cfg.num_heads * cfg.head_dim) % 16 == 0
    assert cfg.d_ff % 16 == 0
