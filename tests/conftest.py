"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
host's single device; multi-device tests spawn subprocesses with their own
flags (see helpers.run_multidevice)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N virtual host devices."""
    from repro.launch.env import subprocess_env
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{res.stdout}\n"
            f"STDERR:\n{res.stderr[-3000:]}")
    return res.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
