"""Preemption and straggler-detection satellites (ISSUE 4).

SIGTERM-driven snapshot: a training subprocess receives SIGTERM mid-run,
commits a checkpoint, and exits 0; a ``resume=True`` follow-up restores
it and finishes the job.  Measured straggler detection: a worker whose
*data source* is genuinely slow gets detected by the step-time EMA and
dropped by ``bsp+backup:k`` — cross-validated against the equivalent
plan-scheduled ``slow:wIxF@t`` run on both backends.
"""
import os
import signal
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.elastic import StepTimeEMA, latest_checkpoint
from repro.elastic.recovery import fit_elastic
from repro.train import Strategy, Trainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batches(slow_worker=None, delay=0.03):
    def batches(t, w):
        if slow_worker is not None and w == slow_worker:
            time.sleep(delay)
        k = jax.random.fold_in(KEY, t * 100 + w)
        X = jax.random.normal(k, (16, 8))
        return {"X": X, "y": X @ W_TRUE}
    return batches


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((8, 1))}


# --------------------------------------------------------- detector unit
def test_step_time_ema_ranking_and_reshard():
    d = StepTimeEMA(3, alpha=0.5, warmup=2)
    assert not d.ready
    for _ in range(2):
        d.observe(0, 0.01)
        d.observe(1, 0.10)
        d.observe(2, 0.02)
    assert d.ready
    assert d.drop_set(1) == frozenset({1})
    assert np.argmax(d.factors()) == 1


def test_step_time_ema_discards_first_sample():
    """A worker's first measurement absorbs one-time costs (JIT compile
    of the shared step) — it must not rank a healthy worker slowest."""
    d = StepTimeEMA(2, warmup=2)
    d.observe(0, 5.0)            # compile hits whoever runs first
    d.observe(1, 0.01)
    d.observe(0, 0.01)
    d.observe(1, 0.50)           # the real straggler
    assert d.ready
    assert d.drop_set(1) == frozenset({1})


def test_step_time_ema_reshard_and_state():
    d = StepTimeEMA(3, alpha=0.5, warmup=2)
    for _ in range(2):
        d.observe(0, 0.01)
        d.observe(1, 0.10)
        d.observe(2, 0.02)
    d.reshard([0, 2], 3)                 # worker 1 leaves, a new slot joins
    assert not d.ready                   # the grown slot must re-warm
    assert d.ema[2] is None
    st = d.state()
    d2 = StepTimeEMA(3)
    d2.load_state(st)
    assert d2.ema == d.ema and d2.count == d.count


# ------------------------------------------- measured vs scheduled (sim)
def test_sim_detection_cross_validates_scheduled_plan():
    # scheduled: slow:w1x10@0 makes worker 1 the ranked straggler
    _, h_sched, m = Trainer(
        Strategy(sync="bsp", backup=1, workers=4, lr=0.05, backend="sim")
    ).fit(grad_fn, P0, make_batches(), 6, plan="slow:w1x10@0")
    assert all(h["dropped"] == [1] for h in h_sched)

    # measured: worker 1's data source is *actually* slow; after the
    # 2-step warmup the EMA ranking takes over from the schedule
    eng = Strategy(sync="bsp", backup=1, workers=4, lr=0.05, detect=True,
                   backend="sim").build(grad_fn)
    _, h_det, _ = eng.run(P0, make_batches(slow_worker=1), 6)
    assert [h["dropped"] for h in h_det][:2] == [[3], [3]]   # warmup rank
    assert all(h["dropped"] == [1] for h in h_det[2:])
    # post-warmup the measured drop set equals the scheduled one, so the
    # loss trajectories coincide too (same participants, same batches)
    assert [h["dropped"] for h in h_det[2:]] == \
        [h["dropped"] for h in h_sched[2:]]
    assert np.argmax(eng.inner.detector.factors()) == 1
    assert eng.metrics()["dropped_updates"] == 6


def test_detect_spec_grammar():
    s = Strategy.parse("bsp+backup:1+detect/ring/none@4")
    assert (s.backup, s.detect) == (1, True)
    assert Strategy.parse(s.spec()) == s
    assert Strategy.parse("bsp+detect").detect
    with pytest.raises(ValueError):
        Strategy(sync="ssp", detect=True)


# ------------------------------------------ measured detection on device
SCRIPT_DEVICE_DETECT = r"""
import time
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
def batches(t, w):
    if w == 0:
        time.sleep(0.05)
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((8, 1))}

eng = Strategy.parse("bsp+backup:1+detect/ring/none@4", lr=0.05,
                     bucket_mb=1e-4, backend="device").build(grad_fn)
_, hist, _ = eng.run(P0, batches, 6)
drops = [h["dropped"] for h in hist]
assert drops[:2] == [[3], [3]], drops          # warmup: scheduled ranking
assert all(d == [0] for d in drops[2:]), drops  # measured straggler w0
# the measured drop set matches what a slow:w0 plan would schedule
sched = Strategy.parse("bsp+backup:1/ring/none@4", lr=0.05, bucket_mb=1e-4,
                       backend="device").build(grad_fn)
sched.set_slowdown(0, 10.0)
_, h2, _ = sched.run(P0, batches, 4)
assert all(h["dropped"] == [0] for h in h2)
print("DEVICE-DETECT-OK")
"""


def test_device_detection_4dev(multidevice):
    out = multidevice(SCRIPT_DEVICE_DETECT, 4)
    assert "DEVICE-DETECT-OK" in out


# --------------------------------------------------- SIGTERM preemption
CHILD = r"""
import sys, time
import jax, jax.numpy as jnp
from repro.train import Strategy, Trainer

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
def batches(t, w):
    time.sleep(0.15)
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((8, 1))}
p, h, m = Trainer(Strategy(sync="bsp", workers=2, lr=0.05,
                           backend="sim")).fit(
    grad_fn, P0, batches, 200, plan="", checkpoint_dir=sys.argv[1],
    checkpoint_every=1)
print("PREEMPTED" if m["preempted"] else "FINISHED",
      m["preempt_step"], flush=True)
"""


def test_sigterm_snapshot_and_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, "-c", CHILD, str(tmp_path)],
                            env=env, stdout=subprocess.PIPE, text=True)
    # wait until the child has committed at least one cadence checkpoint
    deadline = time.time() + 60
    while latest_checkpoint(str(tmp_path)) is None:
        assert time.time() < deadline, "child never checkpointed"
        assert proc.poll() is None, "child died early"
        time.sleep(0.5)
    time.sleep(2)
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=120)
    assert proc.returncode == 0, out
    assert "PREEMPTED" in out

    ck = latest_checkpoint(str(tmp_path))
    assert ck is not None
    preempt_step = int(ck.rsplit("_", 1)[1])
    assert preempt_step > 0

    # resume picks up the preemption snapshot and runs to completion
    p, h, m = fit_elastic(
        Strategy(sync="bsp", workers=2, lr=0.05, backend="sim"), grad_fn,
        P0, make_batches(), preempt_step + 5, "",
        checkpoint_dir=str(tmp_path), resume=True)
    assert m["resumed_from"] == preempt_step
    assert not m["preempted"]
    assert len(h) == 5                   # only the remaining steps ran
    assert all(np.isfinite(x["loss"]) for x in h)


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    p, h, m = fit_elastic(
        Strategy(sync="bsp", workers=2, lr=0.05, backend="sim"), grad_fn,
        P0, make_batches(), 4, "", checkpoint_dir=str(tmp_path),
        resume=True)
    assert m["resumed_from"] is None and len(h) == 4


def test_resume_does_not_refire_consumed_events(tmp_path):
    """The crash at step 6 rolls back to the step-4 checkpoint, so the
    newest snapshot a resumed incarnation sees is *earlier* than the
    crash it already consumed — the consumed record in the checkpoint
    (not the resume step) must prevent the crash firing twice."""
    strat = Strategy(sync="bsp", workers=4, lr=0.05, backend="sim")
    p, h, m = fit_elastic(strat, grad_fn, P0, make_batches(), 8,
                          "crash:w1@6", checkpoint_dir=str(tmp_path),
                          checkpoint_every=2)
    assert len(m["recoveries"]) == 1 and m["final_workers"] == 3
    # a new incarnation resumes the same dir with the same plan: the
    # crash must NOT fire again (it would shrink to 2 workers)
    p2, h2, m2 = fit_elastic(strat, grad_fn, P0, make_batches(), 10,
                             "crash:w1@6", checkpoint_dir=str(tmp_path),
                             checkpoint_every=2, resume=True)
    assert m2["resumed_from"] is not None
    assert m2["recoveries"] == []
    assert m2["final_workers"] == 3


def test_resume_then_rollback_does_not_duplicate_history(tmp_path):
    """A rollback after resume must not truncate this incarnation's
    history with the previous incarnation's history_len frame — the
    restored checkpoint is re-committed at resume with history_len=0."""
    strat = Strategy(sync="bsp", workers=4, lr=0.05, backend="sim")
    # incarnation 1: plain run leaves cadence checkpoints (latest at 6)
    fit_elastic(strat, grad_fn, P0, make_batches(), 7, "",
                checkpoint_dir=str(tmp_path), checkpoint_every=3)
    # incarnation 2 resumes at 6 and crashes at 8: rollback must land on
    # the re-committed step-6 frame and yield exactly one event per step
    p, h, m = fit_elastic(strat, grad_fn, P0, make_batches(), 10,
                          "crash:w1@8", checkpoint_dir=str(tmp_path),
                          checkpoint_every=100, resume=True)
    assert m["resumed_from"] == 6
    (r,) = m["recoveries"]
    assert r["restored_step"] == 6
    assert [e["step"] for e in h] == list(range(6, 10))   # no duplicates


# -------------------------------------------------- incremental snapshots
def test_incremental_save_links_unchanged_shards_and_restores_bitwise(
        tmp_path):
    """Periodic saves hash-skip unchanged shards (hard-linked from the
    previous snapshot); restore is bitwise either way."""
    from repro.checkpoint.store import (load_checkpoint, read_manifest,
                                        save_checkpoint)
    tree = {"a": np.arange(64, dtype=np.float32),
            "b": np.ones((32,), np.float32),
            "c": np.full((16,), 7, np.int32)}
    base = str(tmp_path / "step_000001")
    # hash_leaves opts the base in as a linkable incremental anchor
    # (engine snapshots always set it; plain saves skip the sha256 cost)
    save_checkpoint(base, tree, step=1, shard_bytes=200, hash_leaves=True)
    # change exactly one leaf; the others' shards must be linked
    tree2 = dict(tree, a=tree["a"] + 1)
    nxt = str(tmp_path / "step_000002")
    m2 = save_checkpoint(nxt, tree2, step=2, shard_bytes=200,
                         incremental_from=base)
    assert m2["shards"] > 1
    assert 1 <= m2["linked_shards"] < m2["shards"]
    # linked files share an inode with the base checkpoint's
    linked = [i for i in range(m2["shards"])
              if all(r["shard"] != i or r["name"] != "a"
                     for r in m2["leaves"])]
    shared = sum(
        os.stat(os.path.join(nxt, f"shard_{i}.npz")).st_ino
        == os.stat(os.path.join(base, f"shard_{i}.npz")).st_ino
        for i in linked)
    assert shared >= 1
    # restore is bitwise identical to what was saved
    got, step = load_checkpoint(nxt, tree2)
    assert step == 2
    for k in tree2:
        np.testing.assert_array_equal(got[k], tree2[k])
    # deleting the base must not tear the incremental snapshot (hard
    # links keep the inode alive)
    import shutil
    shutil.rmtree(base)
    got2, _ = load_checkpoint(nxt, tree2)
    for k in tree2:
        np.testing.assert_array_equal(got2[k], tree2[k])
    assert read_manifest(nxt)["linked_shards"] == m2["linked_shards"]


def test_elastic_cadence_saves_are_incremental_and_bitwise(tmp_path):
    """An SSP run's idle worker leaves its pulled copy unchanged between
    cadence snapshots — that shard must hash-skip (hard-link) — and a
    restore from an incremental snapshot is bitwise equal to the
    exported state."""
    from repro.checkpoint.store import read_manifest
    from repro.elastic.recovery import (latest_checkpoint,
                                        restore_engine_state,
                                        save_engine_state)
    # worker 3's period (97 ticks) guarantees it never fires within the
    # run, so its pulled copy is a byte-identical leaf at every save
    strat = Strategy(sync="ssp", staleness=5, workers=4, lr=0.05,
                     periods=(1, 1, 1, 97), backend="sim")
    eng = strat.build(grad_fn)
    st = eng.init(P0)
    paths = []
    for t in range(3):
        st, _ = eng.step(st, make_batches(), t)
        p = str(tmp_path / f"step_{t:06d}")
        # tiny shards: each leaf lands in its own shard, so the
        # unchanged pulled copies are individually linkable
        save_engine_state(p, eng, st, t, 0, shard_bytes=64,
                          incremental_from=(paths[-1] if paths else None))
        paths.append(p)
    last = read_manifest(paths[-1])
    assert last["linked_shards"] >= 1, last    # the idle worker's pull
    # bitwise: restore the newest snapshot into a fresh engine
    eng2 = strat.build(grad_fn)
    assert latest_checkpoint(str(tmp_path)) == paths[-1]
    st2, meta = restore_engine_state(paths[-1], eng2, P0)
    a1, _ = eng.export_state(st)
    a2, _ = eng2.export_state(st2)
    for x, y in zip(jax.tree.leaves(a1), jax.tree.leaves(a2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_plan_run_consumed_record_roundtrip():
    from repro.elastic import EventPlan
    run = EventPlan.parse("slow:w0x2@3,crash:w1@5").start()
    run.take_one(5)
    assert run.consumed_specs() == ["slow:w0x2@3"]
    fresh = EventPlan.parse("slow:w0x2@3,crash:w1@5").start()
    fresh.mark_consumed(run.consumed_specs())
    assert [e.spec() for e in fresh.pending] == ["crash:w1@5"]
    # unknown specs are ignored (a plan may change between incarnations)
    fresh.mark_consumed(["resize:9@99"])
    assert len(fresh.pending) == 1
