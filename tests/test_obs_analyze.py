"""Trace analytics layer: step attribution, overlap bounds, pipeline
bubble accounting, serve latency extraction, SLO burn-rate alerting, and
the cross-PR bench regression gate (docs/observability.md,
"Analysis & SLOs")."""
import json
import shutil

import jax.numpy as jnp
import pytest

from repro.obs.analyze import (analyze, overlap_efficiency,
                               pipeline_accounting, request_latencies,
                               serve_summary, step_attribution)
from repro.obs.slo import Objective, SLOMonitor, evaluate_trace
from repro.obs.trace import TraceRecorder, strip_wall

REPO = __file__.rsplit("/tests/", 1)[0]


# --------------------------------------------------------- attribution
def _train_trace():
    """Two steps with compute/exchange inside, a snapshot between them.
    On the tick basis (strip_wall) every duration is exact integer
    arithmetic."""
    rec = TraceRecorder()
    # step 0: ticks [0, 5]; compute [1, 2]; exchange [3, 4]
    with rec.span("step", pid="train", tid="loop",
                  clock=("train_step", 0)):
        with rec.span("compute", pid="train", tid="loop"):
            pass
        with rec.span("exchange", pid="train", tid="loop"):
            pass
    # a snapshot between steps: ticks [6, 7] on the elastic track
    with rec.span("snapshot", pid="elastic", tid="events"):
        pass
    # step 1: ticks [8, 13]
    with rec.span("step", pid="train", tid="loop",
                  clock=("train_step", 1)):
        with rec.span("compute", pid="train", tid="loop"):
            pass
        with rec.span("exchange", pid="train", tid="loop"):
            pass
    return strip_wall(rec.to_chrome())


def test_step_attribution_windows_and_residual():
    attr = step_attribution(_train_trace())
    assert attr is not None
    assert attr["basis"] == "ticks"          # wall was stripped
    s0, s1 = attr["steps"]
    # step 0 window = its own extent [0, 5]
    assert s0["total"] == 5.0
    assert s0["compute"] == 1.0 and s0["comm"] == 1.0
    assert s0["snapshot"] == 0.0             # happened after step 0 ended
    assert s0["stall"] == 3.0                # residual
    # step 1 window = [prev end 5, end 13]: the between-step snapshot is
    # charged to the step that waited for it
    assert s1["total"] == 8.0
    assert s1["snapshot"] == 1.0
    assert s1["stall"] == 5.0
    for row in (s0, s1):
        assert row["attributed_pct"] == pytest.approx(100.0)
    assert attr["attributed_pct_min"] >= 95.0
    assert attr["attributed_pct_max"] <= 105.0
    # totals/fractions are consistent and cover the taxonomy
    assert attr["totals"]["total"] == 13.0
    assert sum(attr["fractions"].values()) == pytest.approx(1.0)


def test_step_attribution_wall_basis_when_present():
    rec = TraceRecorder()
    with rec.span("step", pid="train", tid="loop",
                  clock=("train_step", 0)):
        with rec.span("compute", pid="train", tid="loop"):
            pass
    attr = step_attribution(rec.to_chrome())
    assert attr["basis"] == "wall"
    assert attr["attributed_pct_min"] == pytest.approx(100.0)


def test_step_attribution_none_without_steps():
    rec = TraceRecorder()
    rec.counter("wire_bytes", {"cumulative": 1.0}, pid="train")
    assert step_attribution(rec.to_chrome()) is None


# ---------------------------------------------------- overlap efficiency
def _exchange_trace(no, tictac, issue):
    rec = TraceRecorder()
    with rec.span("exchange", pid="train", tid="loop",
                  clock=("train_step", 0), n_buckets=3,
                  modeled_no_overlap_us=no,
                  modeled_tictac_overlap_us=tictac,
                  modeled_issue_overlap_us=issue):
        pass
    return rec.to_chrome()


def test_overlap_efficiency_in_bounds():
    ov = overlap_efficiency(_exchange_trace(100.0, 60.0, 70.0))
    assert ov is not None and ov["all_in_bounds"]
    assert ov["exchanges"][0]["efficiency"] == pytest.approx(0.75)


def test_overlap_efficiency_violations_flagged():
    assert not overlap_efficiency(
        _exchange_trace(100.0, 60.0, 120.0))["all_in_bounds"]
    assert not overlap_efficiency(
        _exchange_trace(100.0, 60.0, 40.0))["all_in_bounds"]
    # degenerate plan (single bucket): no == tictac -> efficiency 1.0
    ov = overlap_efficiency(_exchange_trace(50.0, 50.0, 50.0))
    assert ov["all_in_bounds"]
    assert ov["exchanges"][0]["efficiency"] == 1.0


def test_overlap_efficiency_none_without_model_args():
    rec = TraceRecorder()
    with rec.span("exchange", pid="train", tid="loop"):
        pass
    assert overlap_efficiency(rec.to_chrome()) is None


def test_commplan_stamps_modeled_bounds():
    """The real CommPlan exchange span carries the three modeled times
    and its issue order lies between the serial and TicTac bounds."""
    from repro.comm.plan import CommPlan
    from repro.core.compression import Compressor
    params = {"a": jnp.zeros((64, 8)), "b": jnp.zeros((130,))}
    plan = CommPlan.plan(params, axis="w", n=4, topology="ring",
                         compressor=Compressor("onebit"), wire="measured",
                         bucket_mb=1e-4)
    rec = TraceRecorder()
    plan.emit_trace(rec, clock=("train_step", 0))
    ov = overlap_efficiency(rec.to_chrome())
    assert ov is not None and ov["all_in_bounds"]
    ex = ov["exchanges"][0]
    assert ex["tictac_overlap_us"] <= ex["no_overlap_us"]
    assert 0.0 <= ex["efficiency"] <= 1.0


# --------------------------------------------------- pipeline accounting
@pytest.mark.parametrize("stages,micro", [(2, 4), (4, 8), (2, 2), (3, 7)])
def test_pipeline_accounting_matches_analytic(stages, micro):
    from repro.parallel.engine import emit_pipeline_trace
    rec = TraceRecorder()
    emit_pipeline_trace(rec, stages, micro, clock=("train_step", 0))
    pp = pipeline_accounting(rec.to_chrome())
    assert pp is not None and len(pp["pipes"]) == 1
    row = pp["pipes"][0]
    # the schedule model is exact: bubble cells = s * (ticks - m)
    ticks = micro + stages - 1
    assert row["ticks"] == ticks
    assert row["bubble_ticks"] == stages * (ticks - micro)
    assert row["active_ticks"] == stages * micro
    # analytic_bubble is rounded to 6 decimals in the trace args
    assert row["measured_bubble"] == pytest.approx(
        row["analytic_bubble"], abs=1e-5)
    assert pp["rel_err_max"] == pytest.approx(0.0, abs=1e-5)


def test_pipeline_accounting_none_without_pipe():
    rec = TraceRecorder()
    with rec.span("step", pid="train", tid="loop"):
        pass
    assert pipeline_accounting(rec.to_chrome()) is None


def test_emit_pipeline_trace_disabled_is_noop():
    from repro.obs.trace import NullRecorder
    from repro.parallel.engine import emit_pipeline_trace
    emit_pipeline_trace(NullRecorder(), 2, 4)   # must not raise


# -------------------------------------------------------- serve extract
def _serve_trace(n=4, stalls=(1.0, 2.0)):
    """Synthetic lifecycle tracks mirroring serve/engine.py's schema:
    rid i arrives at 0, first token at 2+i, finishes at 8+i having
    generated 4 tokens -> ttft = 2+i, tpot = 2.0."""
    rec = TraceRecorder()
    for i in range(n):
        tid = f"req{i}"
        rec.begin("queued", pid="serve", tid=tid,
                  clock=("serve_iter", 0.0), rid=i, arrival=0.0)
        rec.end(pid="serve", tid=tid)
        rec.begin("prefill", pid="serve", tid=tid,
                  clock=("serve_iter", 1.0 + i), rid=i)
        rec.end(pid="serve", tid=tid)
        rec.begin("decode", pid="serve", tid=tid,
                  clock=("serve_iter", 2.0 + i), rid=i)
        rec.end(pid="serve", tid=tid, generated=4)
        rec.instant("done", pid="serve", tid=tid,
                    clock=("serve_iter", 8.0 + i), rid=i, generated=4)
    for t in stalls:
        rec.instant("admission_stall", pid="serve", tid="engine",
                    clock=("serve_iter", t))
    for t in range(12):
        rec.counter("slots", {"used": 1.0, "free": 3.0}, pid="serve",
                    clock=("serve_iter", float(t)))
    return rec.to_chrome()


def test_request_latencies_and_summary():
    tr = _serve_trace()
    rows = request_latencies(tr)
    assert [r["rid"] for r in rows] == [0, 1, 2, 3]
    assert [r["ttft"] for r in rows] == [2.0, 3.0, 4.0, 5.0]
    assert all(r["tpot"] == pytest.approx(2.0) for r in rows)
    s = serve_summary(tr)
    assert s["requests"] == 4
    assert s["ttft_p99"] == 5.0
    assert s["tpot_p50"] == pytest.approx(2.0)
    assert s["admission_stalls"] == 2
    assert s["slo_burn_alerts"] == 0


def test_analyze_bundles_sections():
    out = analyze(_serve_trace())
    assert out["validation"]["errors"] == []
    assert out["serve"]["requests"] == 4
    assert out["attribution"] is None        # no train spans here
    assert out["pipeline"] is None


# ----------------------------------------------------------------- SLOs
def test_objective_parse():
    o = Objective.parse("ttft_p99<8")
    assert (o.metric, o.threshold) == ("ttft", 8.0)
    assert o.budget == pytest.approx(0.01)
    assert o.bad(8.5) and not o.bad(8.0)
    r = Objective.parse("stall_rate<=0.1")
    assert (r.metric, r.budget, r.threshold) == ("stall", 0.1, 0.0)
    assert r.bad(1.0) and not r.bad(0.0)
    assert Objective.parse("tpot_p50 < 1.5").threshold == 1.5
    for bad in ["ttft<8", "ttft_p0<8", "ttft_p100<8", "x_rate<0",
                "x_rate<1.5", "nonsense", "ttft_p99<"]:
        with pytest.raises(ValueError):
            Objective.parse(bad)


def test_slo_monitor_multiwindow_burn():
    mon = SLOMonitor(["ttft_p99<8"], long_window=10.0, short_window=2.0,
                     factor=2.0)
    # sustained badness: both windows burn -> firing
    for t in range(1, 11):
        mon.observe("ttft", float(t), 20.0)
    assert mon.firing(10.0)
    row = mon.evaluate(10.0)[0]
    assert row["burn_long"] == pytest.approx(100.0)   # 1.0 / 0.01
    # recovery: the short window goes clean first -> alert resets even
    # though the long window still burns
    for t in range(11, 14):
        mon.observe("ttft", float(t), 1.0)
    row = mon.evaluate(13.0)[0]
    assert row["burn_long"] >= 2.0 and row["burn_short"] == 0.0
    assert not row["firing"]
    # no observations in window -> no evidence, no alarm
    assert mon.evaluate(1000.0)[0]["firing"] is False


def test_slo_monitor_requires_objectives():
    with pytest.raises(ValueError):
        SLOMonitor([])


def test_evaluate_trace_fires_on_tight_slo_only():
    tr = _serve_trace()
    hot = evaluate_trace(tr, ["ttft_p99<2"], long_window=16.0,
                         short_window=4.0, factor=1.0)
    assert hot["alerts"], hot
    assert hot["alerts"][0]["objectives"] == ["ttft_p99<2"]
    cold = evaluate_trace(tr, ["ttft_p99<100"], long_window=16.0,
                          short_window=4.0, factor=1.0)
    assert not cold["alerts"]
    # ttft/tpot per request + one stall sample per sampled iteration
    assert hot["observations"] == 2 * 4 + 12


def test_autoscaler_burn_times_force_scale_up():
    from repro.obs.trace import tracing
    from repro.serve.autoscale import AutoscalePolicy, Autoscaler
    pol = AutoscalePolicy(replica_rate=100.0, min_replicas=1,
                          max_replicas=4, interval=5.0,
                          scale_down_patience=2)
    # no arrivals: the rate signal alone never scales up
    quiet = Autoscaler(pol).schedule([], horizon=20.0)
    assert [d.replicas for d in quiet] == [1]
    with tracing() as rec:
        burned = Autoscaler(pol).schedule([], horizon=20.0,
                                          burn_times=[7.0])
    # the burn lands in the (5, 10] decision interval -> forced +1;
    # patience then walks it back down two intervals later
    assert [(d.t, d.replicas) for d in burned] == [
        (0.0, 1), (10.0, 2), (20.0, 1)]
    ups = [ev for ev in rec.events
           if ev["name"] == "autoscale_decision"
           and ev["args"].get("reason") == "slo_burn"]
    assert len(ups) == 1 and ups[0]["args"]["to_replicas"] == 2


# ------------------------------------------------------ regression gate
def test_row_key_identity_fields_only():
    from repro.obs.regress import row_key
    a = {"bench": "x", "strategy": "bsp@8", "workers": 8,
         "wire_bytes_per_step": 100.0, "n_buckets": 7}
    b = dict(a, wire_bytes_per_step=200.0, n_buckets=9)
    assert row_key(a) == row_key(b)          # metrics don't change identity
    assert row_key(a) != row_key(dict(a, workers=4))
    assert row_key(a) != row_key(dict(a, strategy="bsp@4"))


def test_compare_bands_direction_and_range():
    from repro.obs.regress import compare
    base = [{"bench": "b", "strategy": "s", "wire_bytes_per_step": 1000.0,
             "tokens_per_s": 10.0}]
    ok = [{"bench": "b", "strategy": "s", "wire_bytes_per_step": 1000.0,
           "tokens_per_s": 11.0}]            # throughput up = fine
    rep = compare([("pr1", base)], ("pr2", ok))
    assert rep["passed"] and rep["compared"] == 2
    worse = [{"bench": "b", "strategy": "s",
              "wire_bytes_per_step": 2000.0, "tokens_per_s": 8.0}]
    rep = compare([("pr1", base)], ("pr2", worse))
    assert not rep["passed"]
    assert {v["metric"] for v in rep["violations"]} == {
        "wire_bytes_per_step", "tokens_per_s"}
    # range band applies to the current snapshot regardless of history
    bad_range = [{"bench": "b", "strategy": "s2",
                  "traced_overhead_pct": -20.0}]
    rep = compare([("pr1", base)], ("pr2", bad_range))
    assert not rep["passed"]
    assert rep["violations"][0]["kind"] == "range"
    # unmatched keys are skipped, not failed
    rep = compare([("pr1", base)],
                  ("pr2", [{"bench": "new", "strategy": "s",
                            "wire_bytes_per_step": 5.0}]))
    assert rep["passed"] and rep["compared"] == 0


def test_bench_gate_passes_on_committed_lineage():
    from repro.obs.regress import find_bench_files, run_gate
    assert len(find_bench_files(REPO)) >= 3
    report = run_gate(REPO)
    assert report["passed"], report["violations"]
    assert report["compared"] > 0


def test_bench_gate_fails_on_injected_wire_regression(tmp_path):
    """The acceptance scenario: double wire_bytes_per_step in a doctored
    newest snapshot and the gate must fail on exactly that metric."""
    from repro.obs.regress import find_bench_files, load_rows, run_gate
    paths = find_bench_files(REPO)
    for p in paths:
        shutil.copy(p, tmp_path / p.rsplit("/", 1)[1])
    doctored, rows = 0, []
    for row in load_rows(paths[-1]):
        if "wire_bytes_per_step" in row:
            row = dict(row, wire_bytes_per_step=2 * row[
                "wire_bytes_per_step"])
            doctored += 1
        rows.append(row)
    assert doctored > 0, "newest snapshot has no wire rows to doctor"
    with open(tmp_path / "BENCH_pr99.json", "w") as f:
        for row in rows:
            f.write(json.dumps(row, sort_keys=True) + "\n")
    report = run_gate(str(tmp_path))
    assert not report["passed"]
    assert {v["metric"] for v in report["violations"]} == {
        "wire_bytes_per_step"}
    assert len(report["violations"]) == doctored
