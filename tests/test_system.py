"""End-to-end behaviour tests for the survey-taxonomy system:
compose (sync model x architecture x compression) and train a real
(reduced) transformer with each — the system's core promise is that the
taxonomy's features compose."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import Compressor, SyncConfig, SyncEngine
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    batches = make_lm_batches(data)

    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch,
                                     compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    return params, batches, grad_fn


@pytest.mark.parametrize("mode,method", [
    ("bsp", "none"), ("bsp", "onebit"), ("ssp", "none"),
    ("asp", "none"), ("sma", "none"), ("bsp", "dgc"),
])
def test_sync_x_compression_composes_on_transformer(lm_setup, mode, method):
    params, batches, grad_fn = lm_setup
    eng = SyncEngine(
        # seed pinned: the engine's rng stream and the synthetic batch
        # stream are both deterministic, so each cell's trajectory is
        # reproducible on a given platform
        SyncConfig(mode=mode, num_workers=2, lr=0.01, staleness=2, seed=0,
                   compressor=Compressor(method, density=0.05,
                                         ef_gain=2.0)),
        grad_fn)
    p_final, hist, wire = eng.run(params, batches, 10)
    losses = [h["loss"] for h in hist]
    assert all(jnp.isfinite(jnp.float32(l)) for l in losses)
    ratio = (sum(losses[-3:]) / 3) / (sum(losses[:3]) / 3)
    if method == "none":
        assert ratio < 1.0, (mode, method, ratio)    # learning happens
    else:
        # compressed cells: 10 steps at lr=0.01 move the loss by only
        # ~3e-4 relative, so a strict-decrease assertion rides on
        # platform noise.  What this cell actually guards is EF
        # *stability* — the pre-fix failure mode was a climbing loss
        # (ratio >> 1).  Assert a ratio ceiling instead (improvement can
        # only be good); the convergence knobs, if a platform ever lands
        # above it, are the documented
        # ``Compressor(ef_gain=..., min_channel=...)`` kwargs.
        assert ratio < 1.001, (mode, method, ratio)
        # and the compressed update path must actually move parameters —
        # a roundtrip regression to (near-)zero gradients would leave the
        # loss flat and otherwise pass the ceiling unnoticed
        moved = max(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(p_final),
                                    jax.tree.leaves(params)))
        assert moved > 0.0, (mode, method)
    assert wire > 0


def test_ssm_arch_with_data_parallel_sync():
    """Survey claim (§3.2.1): data parallelism applies to ANY architecture —
    verify on the attention-free RWKV."""
    cfg = get_config("rwkv6-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)
    batches = make_lm_batches(data)

    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    eng = SyncEngine(SyncConfig(mode="bsp", num_workers=2, lr=0.01), grad_fn)
    _, hist, _ = eng.run(params, batches, 8)
    assert hist[-1]["loss"] < hist[0]["loss"]
