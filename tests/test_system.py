"""End-to-end behaviour tests for the survey-taxonomy system:
compose (sync model x architecture x compression) and train a real
(reduced) transformer with each — the system's core promise is that the
taxonomy's features compose."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import Compressor, SyncConfig, SyncEngine
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    batches = make_lm_batches(data)

    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch,
                                     compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    return params, batches, grad_fn


@pytest.mark.parametrize("mode,method", [
    ("bsp", "none"), ("bsp", "onebit"), ("ssp", "none"),
    ("asp", "none"), ("sma", "none"), ("bsp", "dgc"),
])
def test_sync_x_compression_composes_on_transformer(lm_setup, mode, method):
    params, batches, grad_fn = lm_setup
    eng = SyncEngine(
        SyncConfig(mode=mode, num_workers=2, lr=0.01, staleness=2,
                   compressor=Compressor(method, density=0.05)),
        grad_fn)
    _, hist, wire = eng.run(params, batches, 10)
    losses = [h["loss"] for h in hist]
    assert all(jnp.isfinite(jnp.float32(l)) for l in losses)
    assert losses[-1] < losses[0], (mode, method)   # learning happens
    assert wire > 0


def test_ssm_arch_with_data_parallel_sync():
    """Survey claim (§3.2.1): data parallelism applies to ANY architecture —
    verify on the attention-free RWKV."""
    cfg = get_config("rwkv6-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)
    batches = make_lm_batches(data)

    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    eng = SyncEngine(SyncConfig(mode="bsp", num_workers=2, lr=0.01), grad_fn)
    _, hist, _ = eng.run(params, batches, 8)
    assert hist[-1]["loss"] < hist[0]["loss"]
