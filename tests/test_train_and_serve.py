"""End-to-end trainer/server behaviour."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.compression import Compressor
from repro.core.federated import FedConfig, run_fedavg
from repro.core.precision import PrecisionPolicy, stochastic_round
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.optim import Adam
from repro.serve import generate
from repro.train import TrainState, make_train_step, train_loop


def _setup(arch="tinyllama-1.1b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    batches = make_lm_batches(data)
    return cfg, model, params, lambda t: batches(t, 0)


@pytest.mark.parametrize("method", ["none", "onebit", "qsgd"])
def test_train_loop_descends(method):
    cfg, model, params, batch_fn = _setup()
    opt = Adam()
    comp = Compressor(method)
    step = make_train_step(model.loss_fn, opt,
                           precision=PrecisionPolicy(
                               compute_dtype="float32"),
                           compressor=comp)
    state = TrainState.create(params, opt, comp)
    state, hist = train_loop(step, state, batch_fn, 40, log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.9, method


def test_generate_shapes_and_determinism():
    cfg, model, params, _ = _setup("rwkv6-7b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out1 = generate(model, params, prompt, 6)
    out2 = generate(model, params, prompt, 6)
    assert out1.shape == (2, 11)
    assert jnp.array_equal(out1, out2)
    assert bool(jnp.all(out1[:, :5] == prompt))
    assert bool(jnp.all((out1 >= 0) & (out1 < cfg.vocab_size)))


def test_fedavg_converges_and_noniid_is_harder():
    key = jax.random.PRNGKey(0)
    W_true = jax.random.normal(key, (8, 1))

    def grad_fn(params, batch):
        def loss(p):
            return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
        return jax.value_and_grad(loss)(params)

    def make_clients(skew):
        clients = []
        for c in range(8):
            def fn(s, c=c):
                k = jax.random.fold_in(key, c * 1000 + s)
                X = jax.random.normal(k, (8, 8))
                if skew:        # each client sees a biased input subspace
                    mask = jnp.zeros((8,)).at[c].set(3.0) + 0.3
                    X = X * mask
                return {"X": X, "y": X @ W_true}
            clients.append(fn)
        return clients

    cfg = FedConfig(num_clients=8, clients_per_round=4, local_steps=4,
                    local_lr=0.05)
    p0 = {"W": jnp.zeros((8, 1))}
    _, hist_iid = run_fedavg(p0, make_clients(False), grad_fn, cfg, 12)
    _, hist_skew = run_fedavg(p0, make_clients(True), grad_fn, cfg, 12)
    assert hist_iid[-1]["loss"] < hist_iid[0]["loss"] * 0.5
    # the non-IID run converges more slowly (Nilsson et al. finding)
    assert hist_skew[-1]["loss"] >= hist_iid[-1]["loss"] * 0.5


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((20000,), 1.0 + 2.0 ** -9)     # halfway-ish in bf16
    keys = jax.random.split(key, 8)
    means = [float(stochastic_round(x, jnp.bfloat16, k)
                   .astype(jnp.float32).mean()) for k in keys]
    est = sum(means) / len(means)
    assert abs(est - float(x[0])) < 1e-3        # unbiased in expectation
    # plain cast is biased for this value
    biased = float(x.astype(jnp.bfloat16).astype(jnp.float32).mean())
    assert abs(biased - float(x[0])) > 5e-4
