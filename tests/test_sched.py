"""Multi-tenant scheduler simulator invariants (survey §3.4)."""
import pytest

from repro.sched import Cluster, POLICIES, make_trace, simulate


def loaded_trace():
    # many jobs, short interarrival -> real queueing
    return make_trace(60, 16, seed=3, mean_interarrival=10.0)


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_all_jobs_finish(policy):
    jobs = loaded_trace()
    r = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy=policy)
    assert r.makespan > 0
    assert r.avg_jct < float("inf")


def test_srtf_beats_fifo_on_jct():
    jobs = loaded_trace()
    fifo = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy="fifo")
    srtf = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy="srtf")
    assert srtf.avg_jct <= fifo.avg_jct * 1.05


def test_gandiva_timeslicing_improves_t90():
    """Time slicing lets more jobs make early progress (where DL loss
    curves earn the most) — Gandiva's motivation."""
    jobs = loaded_trace()
    base = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy="fifo")
    gand = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy="fifo",
                    gandiva=True)
    assert gand.mean_t90 <= base.mean_t90 * 1.10


def test_locality_penalty_applied():
    c = Cluster(n_nodes=2, gpus_per_node=4, cross_node_penalty=1.5)
    assert c.try_alloc(0, 2) == 1.0          # fits one node
    assert c.try_alloc(1, 6) == 1.5          # must spread across nodes
    assert c.try_alloc(2, 1) is None         # cluster full
    c.release(0)
    c.release(1)
    assert c.free_gpus == 8


def test_job_loss_curve_monotone():
    jobs = make_trace(5, 8, seed=0)
    j = jobs[0]
    losses = [j.loss_at(e) for e in range(10)]
    assert all(a >= b for a, b in zip(losses, losses[1:]))
    # diminishing returns: first epoch improves more than the ninth
    assert (losses[0] - losses[1]) > (losses[8] - losses[9])
