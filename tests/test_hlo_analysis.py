"""HLO collective parser + roofline unit tests (deliverables e/g glue)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (_group_size, _traffic,
                                       collective_bytes, summarize_cost)
from repro.launch.roofline import analyze_record, model_flops

HLO = """
HloModule jit_step
ENTRY %main {
  %ar = bf16[1024,512]{1,0} all-reduce(%p0), replica_groups=[16,16]<=[256]
  %ag = f32[64,64]{1,0} all-gather(%p1), replica_groups=[64,4]<=[256]
  %aa = bf16[32]{0} all-to-all(%p2), replica_groups={{0,1,2,3}}
  %cp = f32[16,16]{1,0} collective-permute(%p3)
  %rs = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) reduce-scatter(%p4, %p5), replica_groups=[32,8]<=[256]
  %ars = bf16[100]{0} all-reduce-start(%p6), replica_groups=[1,256]<=[256]
  %ard = bf16[100]{0} all-reduce-done(%ars)
  %not = f32[999,999] dot(%a, %b)
}
"""


def test_group_size_parsing():
    assert _group_size("replica_groups=[16,16]<=[256]") == 16
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("no groups here") == 2


def test_traffic_model():
    assert _traffic("all-reduce", 100, 16) == pytest.approx(2 * 15 / 16 * 100)
    assert _traffic("all-gather", 100, 4) == pytest.approx(0.75 * 100)
    assert _traffic("reduce-scatter", 100, 8) == 700.0
    assert _traffic("collective-permute", 100, 2) == 100.0
    assert _traffic("all-reduce", 100, 1) == 0.0


def test_collective_parser():
    out = collective_bytes(HLO)
    ar = 1024 * 512 * 2
    assert out["all-reduce"] == ar + 200       # -start counted, -done not
    assert out["all-gather"] == 64 * 64 * 4
    assert out["all-to-all"] == 32 * 2
    assert out["collective-permute"] == 16 * 16 * 4
    assert out["reduce-scatter"] == 2 * 8 * 8 * 2
    expected = (2 * 15 / 16 * ar            # ar, S=16
                + 0.75 * 16384               # ag, S=4
                + 0.75 * 64                  # aa, S=4
                + 1024                       # cp
                + 7 * 256                    # rs, S=8
                + 2 * 255 / 256 * 200)       # ars, S=256
    assert out["traffic_weighted"] == pytest.approx(expected)


def test_parser_ignores_non_collectives():
    out = collective_bytes("%d = f32[10,10] dot(%a, %b)\n")
    assert out["traffic_weighted"] == 0


def test_model_flops_train_vs_decode():
    t = model_flops("tinyllama-1.1b", "train_4k")
    d = model_flops("tinyllama-1.1b", "decode_32k")
    assert t == 6.0 * 1100046336 * 256 * 4096
    assert d == 2.0 * 1100046336 * 128
    from repro.configs import ARCHS
    k = model_flops("kimi-k2-1t-a32b", "train_4k")
    assert k == 6.0 * ARCHS["kimi-k2-1t-a32b"].active_param_count() * 256 * 4096


def test_analyze_record_terms():
    rec = {"arch": "tinyllama-1.1b", "shape": "train_4k",
           "cost": {"flops": 197e12, "bytes_accessed": 819e9},
           "collectives": {"traffic_weighted": 50e9}}
    out = analyze_record(rec, 256)
    assert abs(out["compute_s"] - 1.0) < 1e-6
    assert abs(out["memory_s"] - 1.0) < 1e-6
    assert abs(out["collective_s"] - 1.0) < 1e-6
    assert out["dominant"] in ("compute", "memory", "collective")
