"""DataParallelEngine + collectives shim tests (PR 1 tentpole).

Covers: shim resolution on both jax layouts, kwarg translation,
engine-vs-simulator equivalence on 8 virtual devices, kernel-vs-ref
bit-identity through the sharded compressed path, EF state round-trip,
wire accounting, and the TicTac bucket-order timeline model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives
from repro.core.comm_scheduler import LinkModel


# ----------------------------------------------------------------- shim unit
def test_shim_resolves_on_installed_jax():
    fn, origin = collectives.resolve_shard_map()
    assert callable(fn)
    assert origin in ("jax.shard_map", "jax.experimental.shard_map.shard_map")


def test_shim_translates_check_vma_to_old_layout(monkeypatch):
    """A jax exposing only the old check_rep kwarg must receive check_rep."""
    seen = {}

    def old_style(f, mesh=None, in_specs=None, out_specs=None,
                  check_rep=True):
        seen.update(check_rep=check_rep)
        return f
    monkeypatch.setattr(jax, "shard_map", old_style, raising=False)
    collectives.shard_map(lambda x: x, mesh="m", in_specs=(), out_specs=(),
                          check_vma=False)
    assert seen == {"check_rep": False}


def test_shim_translates_to_new_layout(monkeypatch):
    """A jax exposing the promoted jax.shard_map with check_vma gets it
    verbatim, whether the caller wrote check_vma or legacy check_rep."""
    seen = {}

    def new_style(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=True):
        seen.update(check_vma=check_vma)
        return f
    monkeypatch.setattr(jax, "shard_map", new_style, raising=False)
    collectives.shard_map(lambda x: x, mesh="m", in_specs=(), out_specs=(),
                          check_rep=False)
    assert seen == {"check_vma": False}


def test_shim_runs_a_real_shard_map():
    """End-to-end through whatever layout this jax has (single device)."""
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    f = collectives.shard_map(
        lambda x: x * collectives.axis_size("w"), mesh=mesh,
        in_specs=P("w"), out_specs=P("w"), check_vma=False)
    out = f(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


# ------------------------------------------------------------ timeline model
def test_tictac_bucketed_overlap_beats_no_overlap():
    from repro.train import DataParallelConfig, DataParallelEngine
    params = {f"layer{i}": jnp.zeros((256, 256)) for i in range(12)}
    cfg = DataParallelConfig(num_workers=1, bucket_mb=0.5, order="tictac",
                             link=LinkModel(alpha_s=5e-6, beta_Bps=50e9),
                             back_s_per_byte=2e-11)
    eng = DataParallelEngine(cfg, grad_fn=lambda p, b: (jnp.float32(0), p))
    tl = eng.modeled_timeline(params)
    assert tl["n_buckets"] > 1
    assert tl["overlap_s"] < tl["no_overlap_s"]


def test_bucket_plan_covers_every_leaf_once():
    from repro.train import DataParallelConfig, DataParallelEngine
    params = {f"l{i}": jnp.zeros((64, 64)) for i in range(7)}
    eng = DataParallelEngine(
        DataParallelConfig(num_workers=1, bucket_mb=0.03),
        grad_fn=lambda p, b: (jnp.float32(0), p))
    buckets, order, fused = eng._bucket_plan(params)
    covered = sorted(i for b in buckets for i in b)
    assert covered == list(range(7))
    assert sorted(order) == list(range(len(fused)))


# ----------------------------------------------- sharded engine (subprocess)
SCRIPT_ENGINE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core import Compressor, SyncConfig, SyncEngine
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.train import DataParallelConfig, DataParallelEngine

cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=8, batch_size=2)
batches = make_lm_batches(data)
def grad_fn(p, batch):
    (loss, _), g = jax.value_and_grad(
        lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
        has_aux=True)(p)
    return loss, g

K, steps = 8, 3
# --- bsp/none: device-sharded engine == single-device simulator ---
dp = DataParallelEngine(DataParallelConfig(num_workers=K, lr=0.01), grad_fn)
p_dp, h_dp, w_dp = dp.run(params, batches, steps)
sim = SyncEngine(SyncConfig(mode="bsp", num_workers=K, lr=0.01), grad_fn)
p_sim, h_sim, w_sim = sim.run(params, batches, steps)
for a, b in zip(h_dp, h_sim):
    assert abs(a["loss"] - b["loss"]) <= 1e-4, (a, b)
pd = max(float(jnp.max(jnp.abs(x - y)))
         for x, y in zip(jax.tree.leaves(p_dp), jax.tree.leaves(p_sim)))
assert pd <= 1e-4, pd
assert w_dp == w_sim, (w_dp, w_sim)
print("ENGINE-MATCHES-SIM")

# --- compressed path: Pallas kernel vs jnp oracle, bit-identical losses ---
losses = {}
for backend in ("ref", "kernel"):
    eng = DataParallelEngine(
        DataParallelConfig(num_workers=K, lr=0.01, topology="butterfly",
                           compressor=Compressor("onebit",
                                                 backend=backend)),
        grad_fn)
    _, h, w = eng.run(params, batches, 2)
    losses[backend] = [x["loss"] for x in h]
    assert w == eng.wire_bytes_per_step(params) * 2, (
        w, eng.wire_bytes_per_step(params))
assert losses["ref"] == losses["kernel"], losses
print("KERNEL-REF-IDENTICAL")

# --- EF state round-trips: second run from engine state continues sane ---
eng = DataParallelEngine(
    DataParallelConfig(num_workers=K, lr=0.01,
                       compressor=Compressor("dgc", density=0.05)), grad_fn)
p1, h1, w1 = eng.run(params, batches, 2)
assert all(jnp.isfinite(jnp.float32(h["loss"])) for h in h1)
assert w1 == eng.wire_bytes_per_step(params) * 2
print("EF-WIRE-OK")
"""


def test_data_parallel_engine_8dev(multidevice):
    out = multidevice(SCRIPT_ENGINE, 8)
    assert "ENGINE-MATCHES-SIM" in out
    assert "KERNEL-REF-IDENTICAL" in out
    assert "EF-WIRE-OK" in out
