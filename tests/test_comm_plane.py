"""Unified communication plane tests (ISSUE 5 tentpole).

Covers: codec encode/decode roundtrips, schedule consistency (every
worker decodes identical bytes) and the EF telescoping invariant across
all topologies, the wire-byte property (onebit < terngrad < qsgd < none,
and measured-vs-critical-path-model agreement within the documented
error factors), bitwise ``bsp/*/none`` equivalence of the modeled and
measured modes, the dgc cached-wire regression, device SMA vs the
simulator, and the ISSUE acceptance cells (``bsp/ring/onebit@8`` with
``wire=measured`` at ≤0.25× fp32-ring bytes inside the loss band;
``ssp:2/ring/onebit@8:d4.t2`` staleness replay).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codecs import make_codec
from repro.comm.transport import (model_error_factor, per_device_bytes,
                                  schedule_tx_bytes)

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:          # hypothesis is an optional dev dep
    HAVE_HYPOTHESIS = False

TOPOLOGIES = ("ring", "tree", "butterfly")
METHODS = ("onebit", "terngrad", "qsgd")


# ------------------------------------------------------------- codec units
@pytest.mark.parametrize("method", METHODS + ("dgc", "none"))
def test_codec_roundtrip_shape_and_finiteness(method):
    codec = make_codec(method) if method != "dgc" else \
        make_codec("dgc", density=0.1)
    seg = jax.random.normal(jax.random.PRNGKey(0), (700,))   # odd length
    planes = codec.encode(seg, jax.random.PRNGKey(1))
    dec = codec.decode(planes)[:700]
    assert dec.shape == seg.shape
    assert bool(jnp.all(jnp.isfinite(dec)))
    if method == "none":
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(seg))


def test_onebit_codec_pads_without_bias():
    """A segment of one sign must decode to its two-bin means with zero
    influence from the pad zeros."""
    codec = make_codec("onebit")
    seg = jnp.full((100,), 3.0)                  # 100 << LANE, all positive
    dec = codec.decode(codec.encode(seg))[:100]
    np.testing.assert_allclose(np.asarray(dec), 3.0, rtol=1e-6)


def test_dgc_codec_counts_only_valid_elements():
    codec = make_codec("dgc", density=0.1)
    seg = jax.random.normal(jax.random.PRNGKey(0), (500,))
    planes = codec.encode(seg)
    nnz = int(codec.sent_elems(planes))
    # ~10% of 500, never counting the 12 pad-row slots
    assert 40 <= nnz <= 75, nnz


# -------------------------------------------------- wire-byte property
def _check_wire_property(n, length):
    fp32 = {t: schedule_tx_bytes(t, n, length, make_codec("none"))
            for t in TOPOLOGIES}
    for topo in TOPOLOGIES:
        tx = {m: schedule_tx_bytes(topo, n, length, make_codec(m))
              for m in METHODS}
        # ordering: 1 bit < 2 bits < 8 bits < fp32, per worker
        assert tx["onebit"] < tx["terngrad"] < tx["qsgd"] < fp32[topo], \
            (topo, n, length, tx, fp32[topo])
        # the critical-path model divided by the documented error factor
        # predicts the measured mean-tx within the side-info/padding slack
        for m in METHODS:
            codec = make_codec(m)
            model = per_device_bytes(topo, n, codec.static_tx_bytes(length))
            predicted = model / model_error_factor(topo, n, exact=False)
            assert predicted == pytest.approx(tx[m], rel=0.25), \
                (topo, m, n, length, predicted, tx[m])


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(n=hst.sampled_from([2, 4, 8, 16]),
           length=hst.integers(min_value=64, max_value=4096))
    def test_wire_bytes_property(n, length):
        # per-chunk payloads below ~64 elements are dominated by row side
        # info (the same reason Compressor has min_channel); the property
        # holds from there up
        _check_wire_property(n, max(length, 64) * n)
else:
    @pytest.mark.parametrize("n,length", [(2, 2048), (4, 4096), (8, 8192)])
    def test_wire_bytes_property(n, length):     # hypothesis-free fallback
        _check_wire_property(n, length)


def test_model_error_factor_is_exact_for_none():
    """For the exact codec the documented factors reconcile the two byte
    measures exactly (no side-info slack)."""
    none = make_codec("none")
    L = 4096
    for n in (2, 4, 8):
        for topo in TOPOLOGIES + ("fully_connected",):
            tx = schedule_tx_bytes(topo, n, L, none)
            model = per_device_bytes(topo, n, 4 * L)
            assert model / model_error_factor(topo, n, exact=True) == \
                pytest.approx(tx, rel=1e-6), (topo, n)


# ---------------------------------------------- schedule consistency (4dev)
SCRIPT_SCHEDULES = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.collectives import shard_map
from repro.comm.codecs import make_codec
from repro.comm.transport import compressed_allreduce, pad_for_schedule

n = 4
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))
L = 1000
x = jax.random.normal(jax.random.PRNGKey(0), (n, L)) * (1 + jnp.arange(n)[:, None])
for topo in ("ring", "tree", "butterfly", "fully_connected"):
    for method in ("onebit", "terngrad", "qsgd", "dgc"):
        codec = make_codec(method) if method != "dgc" else make_codec("dgc", density=0.1)
        Pl = pad_for_schedule(L, n)
        def body(xx, kk):
            flat = jnp.pad(xx[0], (0, Pl - L))
            red, res, sent = compressed_allreduce(flat, "w", topo, codec, kk[0])
            return red[None, :L], res[None, :L], sent[None]
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("w"), P("w")),
                    out_specs=(P("w"), P("w"), P("w")), check_vma=False))
        red, res, sent = f(x, jax.random.split(jax.random.PRNGKey(1), n))
        red, res = np.asarray(red), np.asarray(res)
        # every worker must decode the *identical* reduced vector
        assert np.max(np.abs(red - red[0])) == 0.0, (topo, method)
        # EF telescoping: reduced + sum(residuals) == true sum (fp32 tol)
        true = np.asarray(jnp.sum(x, 0))
        gap = np.max(np.abs(red[0] + res.sum(0) - true)) / np.max(np.abs(true))
        assert gap < 1e-5, (topo, method, gap)
print("SCHEDULES-OK")
"""


def test_codec_schedules_consistent_and_telescoping_4dev(multidevice):
    assert "SCHEDULES-OK" in multidevice(SCRIPT_SCHEDULES, 4)


# --------------------------------- engine integration (subprocess, 4 devices)
SCRIPT_ENGINE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}
def sparse_batch(t, w):
    # step 0 only the first feature is active -> gradient rows are exact
    # zeros -> dgc's quantile threshold degenerates and the sparse
    # payload balloons; later steps are dense
    b = make_batch(t, w)
    if t == 0:
        mask = jnp.zeros((64,)).at[0].set(1.0)
        X = b["X"] * mask
        return {"X": X, "y": X @ W_TRUE}
    return b
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((8192,))}

# --- bsp/*/none: modeled and measured execute bitwise-identically ---
for arch in ("allreduce", "ps"):
    runs = {}
    for wire in ("modeled", "measured"):
        eng = Strategy(sync="bsp", arch=arch, workers=4, lr=0.05,
                       backend="device", wire=wire).build(grad_fn)
        runs[wire] = eng.run(P0, make_batch, 3)
    for a, b in zip(jax.tree.leaves(runs["modeled"][0]),
                    jax.tree.leaves(runs["measured"][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in runs["modeled"][1]] == \
           [h["loss"] for h in runs["measured"][1]], arch
print("NONE-BITWISE-OK")

# --- measured wire ordering through the real engine ---
wires = {}
for comp in ("onebit", "terngrad", "qsgd", "none"):
    eng = Strategy(sync="bsp", workers=4, lr=0.05, compression=comp,
                   backend="device", wire="measured").build(grad_fn)
    _, h, w = eng.run(P0, make_batch, 4)
    assert all(np.isfinite(e["loss"]) for e in h), comp
    wires[comp] = w
assert wires["onebit"] < wires["terngrad"] < wires["qsgd"] < wires["none"], wires
print("ORDERING-OK")

# --- dgc regression: measured bytes are recomputed per bucket per step,
# not cached from step 0 (the step-0 payload here is degenerate-dense) ---
eng = Strategy(sync="bsp", workers=4, lr=0.05, compression="dgc",
               density=0.05, backend="device", wire="measured").build(grad_fn)
st = eng.init(P0)
incs, prev = [], 0
for t in range(3):
    st, _ = eng.step(st, sparse_batch, t)
    incs.append(st["wire"] - prev)
    prev = st["wire"]
assert incs[0] != incs[1], incs   # cached step-0 accounting would repeat
assert incs[1] == incs[2] or abs(incs[1] - incs[2]) < incs[0], incs
print("DGC-PER-STEP-OK", incs)

# --- device SMA cross-validates the simulator (the CommPlan exchange) ---
sim = Strategy(sync="sma", workers=4, lr=0.05, backend="sim").build(grad_fn)
ps, hs, ws = sim.run(P0, make_batch, 6)
dev = Strategy(sync="sma", workers=4, lr=0.05, backend="device").build(grad_fn)
pd, hd, wd = dev.run(P0, make_batch, 6)
ld = max(abs(a["loss"] - b["loss"]) for a, b in zip(hs, hd))
pdiff = max(float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(jax.tree.leaves(ps), jax.tree.leaves(pd)))
assert ld <= 1e-4 and pdiff <= 1e-4 and ws == wd, (ld, pdiff, ws, wd)
# and the SMA engine snapshots/reshards like every other cell
st = dev.init(P0)
st, _ = dev.step(st, make_batch, 0)
arrays, meta = dev.export_state(st)
st2 = dev.import_state(arrays, meta)
st2 = dev.reshard(st2, 2, step=1)
st2, ev = dev.step(st2, make_batch, 1)
assert np.isfinite(ev[0]["loss"])
print("SMA-DEVICE-OK")
"""


def test_comm_plane_engine_4dev(multidevice):
    out = multidevice(SCRIPT_ENGINE, 4)
    for marker in ("NONE-BITWISE-OK", "ORDERING-OK", "DGC-PER-STEP-OK",
                   "SMA-DEVICE-OK"):
        assert marker in out


# ------------------------- bf16 reduce precision × wire=measured accounting
SCRIPT_BF16_WIRE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((8192,))}

def run(precision, comp="none"):
    eng = Strategy(sync="bsp", workers=4, lr=0.05, compression=comp,
                   optimizer="adamw", precision=precision,
                   backend="device", wire="measured").build(grad_fn)
    _, hist, _ = eng.run(P0, make_batch, 6)
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses), (precision, comp)
    return losses, eng.metrics()["measured_step_tx_bytes"]

# --- none@bf16r: the uncompressed reduce travels in 2-byte words, so the
# measured grad exchange is exactly half the fp32 cell's ---
l32, b32 = run("fp32")
l16, b16 = run("bf16r")
assert b16 * 2 == b32, (b16, b32)
# and the loss trajectory holds a loose band around fp32 (bf16 mantissa)
for a, b in zip(l32, l16):
    assert abs(a - b) <= 0.25 * abs(a) + 1e-3, (l32, l16)
print(f"BF16R-HALF-WIRE-OK fp32={b32} bf16r={b16}")

# --- a lossy codec is precision-invariant on the wire: its planes are
# already 1-bit + fp32 scales, whatever dtype the reduce would have used ---
_, ob32 = run("fp32", "onebit")
_, ob16 = run("bf16r", "onebit")
assert ob16 == ob32, (ob16, ob32)
assert ob16 < b16, (ob16, b16)
print("BF16R-CODEC-OK")
"""


def test_bf16_reduce_wire_accounting_4dev(multidevice):
    out = multidevice(SCRIPT_BF16_WIRE, 4)
    assert "BF16R-HALF-WIRE-OK" in out
    assert "BF16R-CODEC-OK" in out


# -------------------------------- ISSUE acceptance (subprocess, 8 devices)
SCRIPT_ACCEPTANCE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.train import Strategy
from repro.parallel import make_tiny_transformer

# --- bsp/ring/onebit@8 wire=measured: <=0.25x fp32-ring bytes AND the
# seed-pinned loss-ratio band of the composition tests (test_system) ---
cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
batches = make_lm_batches(data)
def grad_fn(p, batch):
    (loss, _), g = jax.value_and_grad(
        lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
        has_aux=True)(p)
    return loss, g

eng = Strategy.parse("bsp/ring/onebit@8", lr=0.01, backend="device",
                     wire="measured").build(grad_fn)
p_final, hist, wire = eng.run(params, batches, 10)
m = eng.metrics()
ratio_bytes = m["measured_step_tx_bytes"] / m["fp32_step_tx_bytes"]
assert ratio_bytes <= 0.25, ratio_bytes
losses = [h["loss"] for h in hist]
assert all(np.isfinite(l) for l in losses)
loss_ratio = (sum(losses[-3:]) / 3) / (sum(losses[:3]) / 3)
assert loss_ratio < 1.001, loss_ratio       # the existing EF band
moved = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p_final),
                            jax.tree.leaves(params)))
assert moved > 0.0
print(f"ONEBIT-MEASURED-OK bytes_ratio={ratio_bytes:.4f} "
      f"loss_ratio={loss_ratio:.5f}")

# --- ssp:2/ring/onebit@8:d4.t2 runs end-to-end, staleness schedule
# matches the simulator exactly ---
sparams, smodel = make_tiny_transformer(stages=2, d_model=8, d_ff=16)
KEY = jax.random.PRNGKey(0)
def sbatches(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    x = jax.random.normal(k, (4, 8))
    return {"x": x, "y": x * 0.5}

sim = Strategy(sync="ssp", staleness=2, workers=4, lr=0.05,
               compression="onebit", backend="sim").build(smodel)
_, hs, ws = sim.run(sparams, sbatches, 3)
dev = Strategy.parse("ssp:2/ring/onebit@8:d4.t2", lr=0.05,
                     backend="device").build(smodel)
_, hd, wd = dev.run(sparams, sbatches, 3)
assert [e["worker"] for e in hd] == [e["worker"] for e in hs]
assert [e["max_staleness"] for e in hd] == [e["max_staleness"] for e in hs]
assert all(np.isfinite(e["loss"]) for e in hd)
assert ws == wd, (ws, wd)
# the uncompressed mesh cell additionally cross-validates losses <=1e-4
sim0 = Strategy(sync="ssp", staleness=2, workers=4, lr=0.05,
                backend="sim").build(smodel)
_, hs0, _ = sim0.run(sparams, sbatches, 3)
dev0 = Strategy.parse("ssp:2/ring/none@8:d4.t2", lr=0.05,
                      backend="device").build(smodel)
_, hd0, _ = dev0.run(sparams, sbatches, 3)
ld = max(abs(a["loss"] - b["loss"]) for a, b in zip(hs0, hd0))
assert ld <= 1e-4, ld
print("SSP-MESH-OK")
"""


def test_comm_plane_acceptance_8dev(multidevice):
    out = multidevice(SCRIPT_ACCEPTANCE, 8)
    assert "ONEBIT-MEASURED-OK" in out
    assert "SSP-MESH-OK" in out
