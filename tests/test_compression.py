"""Compressor pytree-level properties (survey Table 2 methods)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import Compressor, METHODS

KEY = jax.random.PRNGKey(0)


def _grads():
    ks = jax.random.split(KEY, 3)
    return {"a": jax.random.normal(ks[0], (33, 7)),
            "b": {"w": jax.random.normal(ks[1], (128,)),
                  "v": jax.random.normal(ks[2], (5, 9, 4))}}


@pytest.mark.parametrize("method", METHODS)
def test_roundtrip_shapes_and_bytes(method):
    g = _grads()
    comp = Compressor(method)
    st = comp.init_state(g)
    out, st2, wire = comp.roundtrip(g, st, jax.random.PRNGKey(1))
    assert jax.tree.structure(out) == jax.tree.structure(g)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        assert a.shape == b.shape and a.dtype == b.dtype
    total = sum(x.size for x in jax.tree.leaves(g)) * 4
    if method == "none":
        assert wire == total
    else:
        assert 0 < wire < total, (method, wire, total)


def test_wire_bytes_ordering():
    """1-bit < ternary < qsgd(8b) < fp32; dgc(1%) smallest-ish."""
    g = _grads()
    wires = {}
    for m in METHODS:
        comp = Compressor(m)
        _, _, wires[m] = comp.roundtrip(g, comp.init_state(g),
                                        jax.random.PRNGKey(1))
    assert wires["onebit"] < wires["terngrad"] < wires["qsgd"] < wires["none"]
    assert wires["dgc"] < wires["qsgd"]


@pytest.mark.parametrize("method", ["onebit", "dgc"])
def test_error_feedback_telescopes_across_steps(method):
    """sum_t decompressed_t + residual_T == sum_t g_t (EF keeps everything)."""
    comp = Compressor(method, density=0.05)
    g0 = _grads()
    st = comp.init_state(g0)
    acc_sent = jax.tree.map(jnp.zeros_like, g0)
    acc_raw = jax.tree.map(jnp.zeros_like, g0)
    for t in range(5):
        g = jax.tree.map(
            lambda x: x * (t + 1) * 0.3, g0)
        out, st, _ = comp.roundtrip(g, st, jax.random.PRNGKey(t))
        acc_sent = jax.tree.map(jnp.add, acc_sent, out)
        acc_raw = jax.tree.map(jnp.add, acc_raw, g)
    total = jax.tree.map(lambda s, e: s + e, acc_sent, st)
    for a, b in zip(jax.tree.leaves(total), jax.tree.leaves(acc_raw)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("method", ["onebit", "terngrad", "qsgd", "dgc"])
def test_kernel_path_matches_ref_path(method):
    g = _grads()
    rng = jax.random.PRNGKey(3)
    c_ref = Compressor(method, backend="ref")
    c_ker = Compressor(method, backend="kernel")
    o1, s1, w1 = c_ref.roundtrip(g, c_ref.init_state(g), rng)
    o2, s2, w2 = c_ker.roundtrip(g, c_ker.init_state(g), rng)
    assert w1 == w2
    for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_onebit_silent_channel_gets_no_noise():
    """A channel row that produced no gradient (and has no residual) must
    reconstruct to exactly zero — the seed's flat-lane layout leaked
    +/- scale noise from unrelated channels into it."""
    comp = Compressor("onebit")
    g = {"embed": jnp.zeros((16, 128)).at[3].set(
        jax.random.normal(KEY, (128,)))}
    st = comp.init_state(g)
    out, st2, _ = comp.roundtrip(g, st)
    silent = jnp.asarray(out["embed"]).copy()
    silent = np.delete(np.asarray(silent), 3, axis=0)
    assert np.all(silent == 0.0), "silent channels must stay silent"
    assert float(jnp.abs(out["embed"][3]).sum()) > 0


def test_onebit_two_bin_reconstruction_is_asymmetric():
    """Seide-style decode: each sign bin decodes to its own bin mean, so a
    skewed row reconstructs with different + and - magnitudes."""
    comp = Compressor("onebit")
    row = jnp.concatenate([jnp.full((96,), 4.0), jnp.full((32,), -0.5)])
    g = {"w": jnp.tile(row, (2, 1))}          # (2, 128): channelwise path
    out, _, _ = comp.roundtrip(g, comp.init_state(g))
    vals = np.unique(np.round(np.asarray(out["w"]), 5))
    assert len(vals) == 2
    assert abs(vals.max() - 4.0) < 1e-4      # + bin mean
    assert abs(vals.min() + 0.5) < 1e-4      # - bin mean


def test_ef_gain_preserves_telescoping():
    """The over-relaxed residual repayment must not break the EF
    bookkeeping: sent + residual == raw for any gain."""
    for gain in (1.0, 2.0, 3.0):
        comp = Compressor("onebit", ef_gain=gain)
        g0 = _grads()
        st = comp.init_state(g0)
        acc = jax.tree.map(jnp.zeros_like, g0)
        raw = jax.tree.map(jnp.zeros_like, g0)
        for t in range(4):
            g = jax.tree.map(lambda x: x * (0.5 + t), g0)
            out, st, _ = comp.roundtrip(g, st)
            acc = jax.tree.map(jnp.add, acc, out)
            raw = jax.tree.map(jnp.add, raw, g)
        tot = jax.tree.map(lambda s, e: s + e, acc, st)
        for a, b in zip(jax.tree.leaves(tot), jax.tree.leaves(raw)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


def test_dgc_threshold_ignores_lane_padding():
    """Quantile threshold must come from the real values only; the padded
    256-lane layout used to dilute it with zeros and over-transmit."""
    comp = Compressor("dgc", density=0.1)
    g = {"w": jax.random.normal(KEY, (10,))}   # 10 real + 246 pad zeros
    out, _, _ = comp.roundtrip(g, comp.init_state(g))
    nz = int(jnp.sum(out["w"] != 0.0))
    assert nz <= 2, f"10%% of 10 values is 1, sent {nz}"


def test_direction_preserved():
    """All compressors keep a positive cosine with the raw gradient."""
    g = _grads()
    flat = lambda t: jnp.concatenate([x.reshape(-1)
                                      for x in jax.tree.leaves(t)])
    for m in ("onebit", "terngrad", "qsgd", "dgc"):
        comp = Compressor(m, density=0.1)
        out, _, _ = comp.roundtrip(g, comp.init_state(g),
                                   jax.random.PRNGKey(4))
        a, b = flat(out), flat(g)
        cos = float(a @ b / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-9))
        assert cos > 0.2, (m, cos)
