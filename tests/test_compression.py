"""Compressor pytree-level properties (survey Table 2 methods)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import Compressor, METHODS

KEY = jax.random.PRNGKey(0)


def _grads():
    ks = jax.random.split(KEY, 3)
    return {"a": jax.random.normal(ks[0], (33, 7)),
            "b": {"w": jax.random.normal(ks[1], (128,)),
                  "v": jax.random.normal(ks[2], (5, 9, 4))}}


@pytest.mark.parametrize("method", METHODS)
def test_roundtrip_shapes_and_bytes(method):
    g = _grads()
    comp = Compressor(method)
    st = comp.init_state(g)
    out, st2, wire = comp.roundtrip(g, st, jax.random.PRNGKey(1))
    assert jax.tree.structure(out) == jax.tree.structure(g)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        assert a.shape == b.shape and a.dtype == b.dtype
    total = sum(x.size for x in jax.tree.leaves(g)) * 4
    if method == "none":
        assert wire == total
    else:
        assert 0 < wire < total, (method, wire, total)


def test_wire_bytes_ordering():
    """1-bit < ternary < qsgd(8b) < fp32; dgc(1%) smallest-ish."""
    g = _grads()
    wires = {}
    for m in METHODS:
        comp = Compressor(m)
        _, _, wires[m] = comp.roundtrip(g, comp.init_state(g),
                                        jax.random.PRNGKey(1))
    assert wires["onebit"] < wires["terngrad"] < wires["qsgd"] < wires["none"]
    assert wires["dgc"] < wires["qsgd"]


@pytest.mark.parametrize("method", ["onebit", "dgc"])
def test_error_feedback_telescopes_across_steps(method):
    """sum_t decompressed_t + residual_T == sum_t g_t (EF keeps everything)."""
    comp = Compressor(method, density=0.05)
    g0 = _grads()
    st = comp.init_state(g0)
    acc_sent = jax.tree.map(jnp.zeros_like, g0)
    acc_raw = jax.tree.map(jnp.zeros_like, g0)
    for t in range(5):
        g = jax.tree.map(
            lambda x: x * (t + 1) * 0.3, g0)
        out, st, _ = comp.roundtrip(g, st, jax.random.PRNGKey(t))
        acc_sent = jax.tree.map(jnp.add, acc_sent, out)
        acc_raw = jax.tree.map(jnp.add, acc_raw, g)
    total = jax.tree.map(lambda s, e: s + e, acc_sent, st)
    for a, b in zip(jax.tree.leaves(total), jax.tree.leaves(acc_raw)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("method", ["onebit", "terngrad", "qsgd", "dgc"])
def test_kernel_path_matches_ref_path(method):
    g = _grads()
    rng = jax.random.PRNGKey(3)
    c_ref = Compressor(method, use_kernel=False)
    c_ker = Compressor(method, use_kernel=True)
    o1, s1, w1 = c_ref.roundtrip(g, c_ref.init_state(g), rng)
    o2, s2, w2 = c_ker.roundtrip(g, c_ker.init_state(g), rng)
    assert w1 == w2
    for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_direction_preserved():
    """All compressors keep a positive cosine with the raw gradient."""
    g = _grads()
    flat = lambda t: jnp.concatenate([x.reshape(-1)
                                      for x in jax.tree.leaves(t)])
    for m in ("onebit", "terngrad", "qsgd", "dgc"):
        comp = Compressor(m, density=0.1)
        out, _, _ = comp.roundtrip(g, comp.init_state(g),
                                   jax.random.PRNGKey(4))
        a, b = flat(out), flat(g)
        cos = float(a @ b / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-9))
        assert cos > 0.2, (m, cos)
