"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle,
the kernel-backend seam (fused encode+EF, codec planes, flash decode, the
trainable flash forward), and the strategy-level backend-parity acceptance
cells on virtual devices.

Hypothesis property tests live in tests/test_kernel_properties.py so these
sweeps run even without the optional dev dep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codecs import make_codec
from repro.kernels import flash_attention as FA
from repro.kernels import onebit, qsgd, terngrad, topk
from repro.kernels.backend import resolve_backend

KEY = jax.random.PRNGKey(42)


# ------------------------------------------------------------ backend seam
def test_resolve_backend_contract(monkeypatch):
    """auto resolves per host (ref on this CPU container), explicit
    choices pass through, garbage is rejected, env overrides auto."""
    assert resolve_backend("kernel") == "kernel"
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("auto") in ("kernel", "ref")
    with pytest.raises(ValueError):
        resolve_backend("bogus")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "kernel")
    assert resolve_backend("auto") == "kernel"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    assert resolve_backend("auto") == "ref"


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 4, 4, 32), (2, 64, 4, 2, 32), (1, 128, 8, 1, 64),
    (2, 96, 4, 2, 64), (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = FA.attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = FA.attention_ref(q, k, v, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = FA.attention(q, k, v, causal=True, window=window,
                       block_q=32, block_k=32)
    ref = FA.attention_ref(q, k, v, causal=True, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    out = FA.attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = FA.attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                           (False, 0)])
def test_flash_attention_grad_matches_ref(causal, window):
    """The trainable entry: flash forward, reference-math VJP.  Both the
    value and every input gradient must match the jnp oracle under
    value_and_grad."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 48, 8, 32))
    k = jax.random.normal(ks[1], (2, 48, 2, 32))
    v = jax.random.normal(ks[2], (2, 48, 2, 32))

    def loss_k(q, k, v):
        return jnp.sum(FA.attention_grad(q, k, v, causal=causal,
                                         window=window) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(FA.attention_ref(q, k, v, causal=causal,
                                        window=window) ** 2)

    vk, gk = jax.value_and_grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    vr, gr = jax.value_and_grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(vk - vr)) < 1e-2
    for a, b in zip(gk, gr):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


@pytest.mark.parametrize("pos", [0, 5, 39])
def test_flash_decode_full_cache(pos):
    ks = jax.random.split(KEY, 3)
    B, H, KV, hd, L = 2, 8, 2, 64, 40
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    ck = jax.random.normal(ks[1], (B, L, KV, hd))
    cv = jax.random.normal(ks[2], (B, L, KV, hd))
    out = FA.decode(q, ck, cv, jnp.int32(pos), block_k=16)
    ref = FA.decode_ref(q, ck, cv, jnp.int32(pos))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("pos", [0, 7, 23, 100])
def test_flash_decode_ring_window(pos):
    """Ring-buffer cache: slots masked by age exactly like the jnp decode
    path, including the partially-filled early steps."""
    ks = jax.random.split(KEY, 3)
    B, H, KV, hd, W = 2, 4, 2, 32, 16
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    ck = jax.random.normal(ks[1], (B, W, KV, hd))
    cv = jax.random.normal(ks[2], (B, W, KV, hd))
    out = FA.decode(q, ck, cv, jnp.int32(pos), window=W, block_k=8)
    ref = FA.decode_ref(q, ck, cv, jnp.int32(pos), window=W)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_attention_module_backend_parity():
    """models.attention routed through the seam: kernel and ref backends
    agree on forward (causal / windowed / encoder) and decode."""
    from repro.configs import get_config
    from repro.models import attention as attn
    cfg = get_config("tinyllama-1.1b").reduced()
    p = attn.attn_init(KEY, cfg)
    B, S = 2, 24
    x = jax.random.normal(KEY, (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for kw in (dict(causal=True), dict(causal=True, window=8),
               dict(causal=False)):
        o_r, _ = attn.attention_forward(p, x, pos, cfg, backend="ref", **kw)
        o_k, _ = attn.attention_forward(p, x, pos, cfg, backend="kernel",
                                        **kw)
        assert float(jnp.max(jnp.abs(o_r - o_k))) < 1e-4, kw
    xt = jax.random.normal(KEY, (B, 1, cfg.d_model))
    caches = {b: attn.init_cache(cfg, B, 8, jnp.float32) for b in
              ("ref", "kernel")}
    for t in range(4):
        outs = {}
        for b in ("ref", "kernel"):
            outs[b], caches[b] = attn.attention_decode(
                p, xt, jnp.int32(t), caches[b], cfg, backend=b)
        assert float(jnp.max(jnp.abs(outs["ref"] - outs["kernel"]))) < 1e-4


# ----------------------------------------------------------- compression
SHAPES = [(8, 128), (64, 256), (100, 512), (3, 1024)]


@pytest.mark.parametrize("R,C", SHAPES)
def test_onebit_kernel_vs_ref(R, C):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.3
    s_k, sc_k, ne_k = onebit.compress(g, e)
    s_r, sc_r, ne_r = onebit.onebit_ref(g, e)
    assert jnp.array_equal(s_k, s_r)
    assert jnp.allclose(sc_k, sc_r, atol=1e-6)
    assert jnp.allclose(ne_k, ne_r, atol=1e-5)


@pytest.mark.parametrize("R,C", SHAPES)
@pytest.mark.parametrize("symmetric", [False, True])
def test_onebit_fused_encode_ef_kernel_vs_ref(R, C, symmetric):
    """The fused single-pass encode+EF kernel (signs, bin means, recon,
    next residual from one read of g/e) is bitwise the jnp oracle."""
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.3
    out_k = onebit.encode_ef(g, e, gain=2.0, symmetric=symmetric,
                             backend="kernel")
    out_r = onebit.encode_ef(g, e, gain=2.0, symmetric=symmetric,
                             backend="ref")
    for a, b in zip(out_k, out_r):
        assert jnp.array_equal(a, b)
    signs, sp, sn, recon, new_e = out_r
    # EF telescoping: recon + residual == g + e (any gain)
    np.testing.assert_allclose(np.asarray(recon + new_e), np.asarray(g + e),
                               atol=1e-5)


def test_onebit_fused_encode_ef_masks_invalid_lanes():
    """Pad lanes flagged invalid must transmit nothing: recon 0, and they
    never contaminate the bin means of real lanes."""
    g = jnp.ones((4, 128)) * 3.0
    valid = jnp.zeros((4, 128), jnp.int8).at[:, :100].set(1)
    for backend in ("ref", "kernel"):
        _, _, _, recon, _ = onebit.encode_ef(
            g, None, valid, backend=backend)
        assert np.all(np.asarray(recon[:, 100:]) == 0.0), backend
        np.testing.assert_allclose(np.asarray(recon[:, :100]), 3.0,
                                   rtol=1e-6)


@pytest.mark.parametrize("R,C", SHAPES)
def test_terngrad_qsgd_kernel_vs_ref(R, C):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    u = jax.random.uniform(ks[1], (R, C))
    t_k, s_k = terngrad.compress(g, u)
    t_r, s_r = terngrad.terngrad_ref(g, u)
    assert jnp.array_equal(t_k, t_r) and jnp.allclose(s_k, s_r)
    q_k, n_k = qsgd.compress(g, u)
    q_r, n_r = qsgd.qsgd_ref(g, u)
    assert jnp.array_equal(q_k, q_r) and jnp.allclose(n_k, n_r)


@pytest.mark.parametrize("R,C", SHAPES)
def test_dispatch_entries_kernel_vs_ref(R, C):
    """The backend-dispatching ops entries (the ones the codecs call)
    agree across backends: terngrad.ternarize, qsgd.quantize,
    topk.sparsify."""
    ks = jax.random.split(KEY, 3)
    g = jax.random.normal(ks[0], (R, C))
    u = jax.random.uniform(ks[1], (R, C))
    e = jax.random.normal(ks[2], (R, C)) * 0.1
    sigma = 2.5 * jnp.std(g)
    gc = jnp.clip(g, -sigma, sigma)
    s = jnp.max(jnp.abs(gc))                 # scalar scale, codec-style
    assert jnp.array_equal(terngrad.ternarize(gc, u, s, backend="kernel"),
                           terngrad.ternarize(gc, u, s, backend="ref"))
    for a, b in zip(qsgd.quantize(g, u, backend="kernel"),
                    qsgd.quantize(g, u, backend="ref")):
        assert jnp.array_equal(a, b)
    th = topk.threshold_for_density(g, e, 0.05)
    for a, b in zip(topk.sparsify(g, e, th, backend="kernel"),
                    topk.sparsify(g, e, th, backend="ref")):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("R,C", SHAPES)
@pytest.mark.parametrize("density", [0.01, 0.1])
def test_topk_kernel_vs_ref(R, C, density):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.1
    th = topk.threshold_for_density(g, e, density)
    o_k, ne_k = topk.compress(g, e, th)
    o_r, ne_r = topk.topk_ref(g, e, th)
    assert jnp.allclose(o_k, o_r) and jnp.allclose(ne_k, ne_r)
    kept = float((o_k != 0).mean())
    assert abs(kept - density) < 0.05


def test_pack_unpack_roundtrip():
    g = jax.random.normal(KEY, (16, 256))
    e = jnp.zeros_like(g)
    signs, _, _ = onebit.compress(g, e)
    words = onebit.pack_bits(signs)
    assert words.shape == (16, 8)           # 32x fewer words
    assert jnp.array_equal(onebit.unpack_bits(words, C=256), signs)


# --------------------------------------------------- codec backend parity
@pytest.mark.parametrize("method,kw", [
    ("onebit", {}), ("terngrad", {}), ("qsgd", {}),
    ("dgc", {"density": 0.05}),
])
def test_codec_backends_bitwise_identical(method, kw):
    """The CommPlan codecs produce bitwise-identical wire planes and EF
    residuals on both backends — what keeps measured wire accounting
    backend-independent."""
    seg = jax.random.normal(jax.random.PRNGKey(5), (700,))
    key = jax.random.PRNGKey(1)
    out = {}
    for backend in ("ref", "kernel"):
        codec = make_codec(method, backend=backend, **kw)
        planes, res = codec.encode_ef(seg, key)
        out[backend] = (planes, res, codec.decode(planes),
                        codec.sent_elems(planes))
    pr, rr, dr, sr = out["ref"]
    pk, rk, dk, sk = out["kernel"]
    assert sorted(pr) == sorted(pk)
    for name in pr:
        assert jnp.array_equal(pr[name], pk[name]), (method, name)
    assert jnp.array_equal(rr, rk)
    assert jnp.array_equal(dr, dk)
    assert int(sr) == int(sk)


def test_dgc_sent_elems_wire_accounting_backend_invariant():
    """Regression for the kernels/topk-backed selection: the traced
    sent_elems count (what measured wire bytes are billed from) must not
    move when the selection runs through the Pallas kernel, across
    densities and degenerate segments."""
    key = jax.random.PRNGKey(9)
    segs = [jax.random.normal(key, (2048,)),
            jnp.zeros((512,)),                       # degenerate: all-zero
            jnp.ones((300,)).at[7].set(100.0)]       # near-constant
    for density in (0.01, 0.05, 0.25):
        for seg in segs:
            counts = {}
            for backend in ("ref", "kernel"):
                codec = make_codec("dgc", density=density, backend=backend)
                counts[backend] = int(codec.sent_elems(codec.encode(seg)))
            assert counts["ref"] == counts["kernel"], (density, seg.shape)


# ------------------------------------- strategy backend parity (subprocess)
SCRIPT_BACKEND_PARITY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((4096,))}

# --- compressed cells: kernel backend inside the existing loss bands ---
for comp in ("onebit", "terngrad", "qsgd"):
    runs = {}
    for kb in ("ref", "kernel"):
        eng = Strategy.parse(f"bsp/ring/{comp}@4", lr=0.05,
                             backend="device", wire="measured",
                             kernel_backend=kb).build(grad_fn)
        runs[kb] = eng.run(P0, make_batch, 3)
    lr_ = [h["loss"] for h in runs["ref"][1]]
    lk = [h["loss"] for h in runs["kernel"][1]]
    ld = max(abs(a - b) for a, b in zip(lr_, lk))
    assert ld <= 1e-4, (comp, lr_, lk)
    assert runs["ref"][2] == runs["kernel"][2], comp   # measured wire bytes
print("CODEC-BACKEND-PARITY-OK")

# --- none cells: the backend knob must be a bitwise no-op ---
for topo in ("ring", "tree", "butterfly"):
    runs = {}
    for kb in ("ref", "kernel"):
        eng = Strategy.parse(f"bsp/{topo}/none@4", lr=0.05,
                             backend="device", wire="measured",
                             kernel_backend=kb).build(grad_fn)
        runs[kb] = eng.run(P0, make_batch, 3)
    for a, b in zip(jax.tree.leaves(runs["ref"][0]),
                    jax.tree.leaves(runs["kernel"][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in runs["ref"][1]] == \
           [h["loss"] for h in runs["kernel"][1]], topo
    assert runs["ref"][2] == runs["kernel"][2], topo
print("NONE-BACKEND-BITWISE-OK")
"""


def test_strategy_kernel_backend_parity_4dev(multidevice):
    out = multidevice(SCRIPT_BACKEND_PARITY, 4)
    assert "CODEC-BACKEND-PARITY-OK" in out
    assert "NONE-BACKEND-BITWISE-OK" in out


# ---------------------------- ISSUE acceptance cell (subprocess, 8 devices)
SCRIPT_ONEBIT8 = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.train import Strategy

cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
batches = make_lm_batches(data)
def grad_fn(p, batch):
    (loss, _), g = jax.value_and_grad(
        lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
        has_aux=True)(p)
    return loss, g

runs = {}
for kb in ("ref", "kernel"):
    eng = Strategy.parse("bsp/ring/onebit@8", lr=0.01, backend="device",
                         wire="measured", kernel_backend=kb).build(grad_fn)
    _, hist, wire = eng.run(params, batches, 4)
    m = eng.metrics()
    runs[kb] = ([h["loss"] for h in hist], wire,
                m["measured_step_tx_bytes"] / m["fp32_step_tx_bytes"])
ld = max(abs(a - b) for a, b in zip(runs["ref"][0], runs["kernel"][0]))
assert ld <= 1e-4, (ld, runs["ref"][0], runs["kernel"][0])
assert runs["ref"][1] == runs["kernel"][1], runs   # bitwise wire bytes
assert runs["ref"][2] <= 0.05, runs["ref"][2]      # the 0.039x fp32-ring cell
print(f"ONEBIT8-BACKEND-OK loss_delta={ld:.2e} "
      f"bytes_ratio={runs['ref'][2]:.4f}")
"""


def test_onebit8_kernel_backend_acceptance(multidevice):
    out = multidevice(SCRIPT_ONEBIT8, 8)
    assert "ONEBIT8-BACKEND-OK" in out
