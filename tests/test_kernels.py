"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle,
plus hypothesis property tests on the compression invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import flash_attention as FA
from repro.kernels import onebit, qsgd, terngrad, topk

KEY = jax.random.PRNGKey(42)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 4, 4, 32), (2, 64, 4, 2, 32), (1, 128, 8, 1, 64),
    (2, 96, 4, 2, 64), (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = FA.attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = FA.attention_ref(q, k, v, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = FA.attention(q, k, v, causal=True, window=window,
                       block_q=32, block_k=32)
    ref = FA.attention_ref(q, k, v, causal=True, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    out = FA.attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = FA.attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


# ----------------------------------------------------------- compression
SHAPES = [(8, 128), (64, 256), (100, 512), (3, 1024)]


@pytest.mark.parametrize("R,C", SHAPES)
def test_onebit_kernel_vs_ref(R, C):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.3
    s_k, sc_k, ne_k = onebit.compress(g, e)
    s_r, sc_r, ne_r = onebit.onebit_ref(g, e)
    assert jnp.array_equal(s_k, s_r)
    assert jnp.allclose(sc_k, sc_r, atol=1e-6)
    assert jnp.allclose(ne_k, ne_r, atol=1e-5)


@pytest.mark.parametrize("R,C", SHAPES)
def test_terngrad_qsgd_kernel_vs_ref(R, C):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    u = jax.random.uniform(ks[1], (R, C))
    t_k, s_k = terngrad.compress(g, u)
    t_r, s_r = terngrad.terngrad_ref(g, u)
    assert jnp.array_equal(t_k, t_r) and jnp.allclose(s_k, s_r)
    q_k, n_k = qsgd.compress(g, u)
    q_r, n_r = qsgd.qsgd_ref(g, u)
    assert jnp.array_equal(q_k, q_r) and jnp.allclose(n_k, n_r)


@pytest.mark.parametrize("R,C", SHAPES)
@pytest.mark.parametrize("density", [0.01, 0.1])
def test_topk_kernel_vs_ref(R, C, density):
    ks = jax.random.split(KEY, 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.1
    th = topk.threshold_for_density(g, e, density)
    o_k, ne_k = topk.compress(g, e, th)
    o_r, ne_r = topk.topk_ref(g, e, th)
    assert jnp.allclose(o_k, o_r) and jnp.allclose(ne_k, ne_r)
    kept = float((o_k != 0).mean())
    assert abs(kept - density) < 0.05


def test_pack_unpack_roundtrip():
    g = jax.random.normal(KEY, (16, 256))
    e = jnp.zeros_like(g)
    signs, _, _ = onebit.compress(g, e)
    words = onebit.pack_bits(signs)
    assert words.shape == (16, 8)           # 32x fewer words
    assert jnp.array_equal(onebit.unpack_bits(words, C=256), signs)


# --------------------------------------------------- hypothesis properties
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 300), st.integers(0, 2**31 - 1))
def test_onebit_error_feedback_telescopes(r, c, seed):
    """EF invariant: compensated gradient == transmitted + residual exactly,
    so no information is ever lost across steps (Seide et al.)."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    e = jax.random.normal(jax.random.fold_in(k, 1), (r, c))
    signs, scale, new_e = onebit.onebit_ref(g, e)
    recon = signs.astype(jnp.float32) * scale + new_e
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g + e),
                               atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 20), st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_terngrad_unbiased_support(r, c, seed):
    """TernGrad values are in {-1,0,1} * s and sign-consistent with g."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    u = jax.random.uniform(jax.random.fold_in(k, 1), (r, c))
    t, s = terngrad.terngrad_ref(g, u)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    nz = np.asarray(t) != 0
    assert np.all(np.sign(np.asarray(t)[nz]) == np.sign(np.asarray(g)[nz]))
    assert float(s) >= 0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 20), st.integers(1, 200), st.integers(0, 2**31 - 1),
       st.sampled_from([3, 15, 127]))
def test_qsgd_reconstruction_bounded(r, c, seed, levels):
    """QSGD: |decompressed - g| <= ||g||/s per element (stochastic rounding
    never moves more than one level)."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    u = jax.random.uniform(jax.random.fold_in(k, 1), (r, c))
    q, norm = qsgd.qsgd_ref(g, u, levels)
    recon = qsgd.decompress(q, norm, s_levels=levels)
    assert np.all(np.abs(np.asarray(recon - g)) <= float(norm) / levels + 1e-5)
