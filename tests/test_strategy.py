"""One-Strategy-API tests (PR 2 tentpole).

Covers: spec-string parsing and roundtrip, backend resolution, the Engine
init/step/finalize protocol vs the composed run, Trainer.fit through the
shared train_loop, deprecation shims (warn once + bitwise-identical
results), the cell registry, and the full device sync×arch×compression
matrix cross-validated against the simulator on 4 virtual devices.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.sync as sync_mod
from repro.core import Compressor, SyncConfig, SyncEngine
from repro.train import (DataParallelConfig, DataParallelEngine, Strategy,
                         Trainer, registered_cells)
from repro.train.strategy import ACCEPTANCE_CELLS, Cell

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((8, 1))}


# ------------------------------------------------------------------ parsing
def test_parse_full_spec():
    s = Strategy.parse("ssp:2/ps/dgc:0.05@8")
    assert (s.sync, s.arch, s.workers, s.staleness) == ("ssp", "ps", 8, 2)
    assert s.compressor.method == "dgc"
    assert s.compressor.density == 0.05


def test_parse_partial_specs_fill_defaults():
    assert Strategy.parse("bsp").arch == "allreduce"
    assert Strategy.parse("bsp").compressor.method == "none"
    s = Strategy.parse("asp/ps@4", lr=0.5)
    assert (s.sync, s.arch, s.workers, s.lr) == ("asp", "ps", 4, 0.5)
    # segments named in the spec win over keyword defaults
    assert Strategy.parse("bsp@4", workers=8).workers == 4
    assert Strategy.parse("ssp:1", staleness=7).staleness == 1


def test_parse_spec_roundtrip():
    for spec in ("bsp/allreduce/none@4", "ssp:3/ps/onebit@8",
                 "asp/allreduce/dgc:0.05@2", "sma/allreduce/none@4"):
        assert Strategy.parse(spec).spec() == spec


def test_parse_rejects_bad_specs():
    for bad in ("", "warp/allreduce", "bsp/mesh", "bsp/allreduce/zip",
                "bsp/allreduce/none/extra",
                "asp:3/ps",                 # staleness bound is ssp-only
                "bsp/allreduce/onebit:0.5",  # density is dgc-only
                "ssp:-1",                   # negative bound never fires
                "sma/allreduce/onebit",     # sma has no compression path
                ):
        with pytest.raises(ValueError):
            Strategy.parse(bad)


# -------------------------------------------------------- backend resolution
def test_auto_backend_falls_back_to_sim_without_devices():
    # host test process has a single device; workers=4 cannot shard
    s = Strategy(sync="bsp", workers=4)
    assert s.resolve_backend() == "sim"
    assert s.build(grad_fn).backend == "sim"


def test_sma_resolves_on_both_backends():
    # device SMA shipped with the comm-plane refactor: auto falls back to
    # sim on this single-device host, but backend="device" is legal now
    assert Strategy(sync="sma", workers=4).resolve_backend() == "sim"
    assert Strategy(sync="sma", workers=4,
                    backend="device").resolve_backend() == "device"
    # one replica on one device still runs end-to-end
    eng = Strategy(sync="sma", workers=1, lr=0.05,
                   backend="device").build(grad_fn)
    _, hist, wire = eng.run(P0, make_batch, 3)
    assert len(hist) == 3 and wire > 0


def test_device_backend_requires_devices():
    with pytest.raises(ValueError, match="devices"):
        Strategy(sync="bsp", workers=64, backend="device").build(grad_fn)


# ------------------------------------------------------------ engine protocol
def test_stepwise_protocol_equals_composed_run():
    mk = lambda: Strategy(sync="ssp", workers=4, lr=0.05, staleness=2,
                          backend="sim").build(grad_fn)
    p_run, hist_run, wire_run = mk().run(P0, make_batch, 6)
    eng = mk()
    st, events = eng.init(P0), []
    for t in range(6):
        st, ev = eng.step(st, make_batch, t)
        events.extend(ev)
    assert [e["loss"] for e in events] == [e["loss"] for e in hist_run]
    assert eng.metrics()["wire_bytes"] == wire_run
    np.testing.assert_array_equal(np.asarray(eng.finalize(st)["W"]),
                                  np.asarray(p_run["W"]))


def test_trainer_fit_drives_shared_loop():
    params, hist, mets = Trainer(
        Strategy(sync="asp", workers=4, lr=0.05, backend="sim")
    ).fit(grad_fn, P0, make_batch, 5)
    assert mets["backend"] == "sim"
    assert mets["spec"] == "asp/allreduce/none@4"
    assert mets["wire_bytes"] > 0
    assert len(hist) >= 5 * 4          # async: >= K updates per global step
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_all_sim_modes_converge_via_strategy():
    for mode in ("bsp", "ssp", "asp", "sma"):
        eng = Strategy(sync=mode, workers=4, lr=0.05,
                       backend="sim").build(grad_fn)
        _, hist, _ = eng.run(P0, make_batch, 25)
        assert hist[-1]["loss"] < hist[0]["loss"] * 0.5, mode


# ---------------------------------------------------------------- registry
def test_registered_cells_cover_acceptance_matrix():
    cells = set(registered_cells())
    assert len(ACCEPTANCE_CELLS) == 18      # {bsp,ssp,asp}x{ar,ps}x{EF set}
    assert ACCEPTANCE_CELLS <= cells
    assert Cell("sma", "allreduce", "none", "sim") in cells


# --------------------------------------------------------- deprecation shims
def test_sync_engine_shim_warns_once_and_is_bitwise_identical():
    sync_mod._WARNED.discard("SyncEngine")
    cfg = SyncConfig(mode="ssp", num_workers=4, lr=0.05, staleness=2,
                     compressor=Compressor("onebit"))
    with pytest.warns(DeprecationWarning, match="SyncEngine"):
        old = SyncEngine(cfg, grad_fn)
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # second construction is quiet
        SyncEngine(cfg, grad_fn)
    p_old, h_old, w_old = old.run(P0, make_batch, 8)
    eng = Strategy(sync="ssp", workers=4, lr=0.05, staleness=2,
                   compression="onebit", backend="sim").build(grad_fn)
    p_new, h_new, w_new = eng.run(P0, make_batch, 8)
    assert [h["loss"] for h in h_old] == [h["loss"] for h in h_new]
    assert w_old == w_new
    np.testing.assert_array_equal(np.asarray(p_old["W"]),
                                  np.asarray(p_new["W"]))


def test_data_parallel_engine_shim_warns_once_and_is_bitwise_identical():
    # num_workers=1 shards onto the host's single device
    sync_mod._WARNED.discard("DataParallelEngine")
    cfg = DataParallelConfig(num_workers=1, lr=0.05,
                             compressor=Compressor("onebit"))
    with pytest.warns(DeprecationWarning, match="DataParallelEngine"):
        old = DataParallelEngine(cfg, grad_fn)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        DataParallelEngine(cfg, grad_fn)
    p_old, h_old, w_old = old.run(P0, make_batch, 5)
    eng = Strategy(sync="bsp", workers=1, lr=0.05, compression="onebit",
                   backend="device").build(grad_fn)
    p_new, h_new, w_new = eng.run(P0, make_batch, 5)
    assert [h["loss"] for h in h_old] == [h["loss"] for h in h_new]
    assert w_old == w_new
    np.testing.assert_array_equal(np.asarray(p_old["W"]),
                                  np.asarray(p_new["W"]))


# -------------------------------------- device matrix (subprocess, 4 devices)
SCRIPT_MATRIX = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
# second leaf exercises the channelwise onebit/dgc reconstruction path
P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}

def maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

STEPS = 3
for sync in ("bsp", "ssp", "asp"):
    for comp in ("none", "onebit", "dgc"):
        base = dict(sync=sync, workers=4, lr=0.05, compression=comp,
                    density=0.1, staleness=2, bucket_mb=1e-4)
        sim = Strategy(backend="sim", **base).build(grad_fn)
        p_sim, h_sim, w_sim = sim.run(P0, make_batch, STEPS)
        results = {}
        for arch in ("allreduce", "ps"):
            dev = Strategy(backend="device", arch=arch, **base).build(grad_fn)
            assert dev.backend == "device"
            p_dev, h_dev, w_dev = dev.run(P0, make_batch, STEPS)
            results[arch] = (p_dev, w_dev)
            # the device engine replays the simulator's event schedule
            assert len(h_dev) == len(h_sim), (sync, comp, arch)
            ldiff = max(abs(a["loss"] - b["loss"])
                        for a, b in zip(h_dev, h_sim))
            assert ldiff <= 1e-4, (sync, comp, arch, ldiff)
            if sync != "bsp":
                assert [e["worker"] for e in h_dev] == \
                       [e["worker"] for e in h_sim]
                assert [e["max_staleness"] for e in h_dev] == \
                       [e["max_staleness"] for e in h_sim]
            # wire accounting identical to the simulator's
            assert w_dev == w_sim, (sync, comp, arch, w_dev, w_sim)
        pd = maxdiff(results["allreduce"][0], results["ps"][0])
        assert pd <= 1e-5, (sync, comp, pd)
        assert results["allreduce"][1] == results["ps"][1]
        print(f"CELL-OK {sync} {comp}")
print("DEVICE-MATRIX-OK")
"""


def test_strategy_device_matrix_4dev(multidevice):
    out = multidevice(SCRIPT_MATRIX, 4)
    assert out.count("CELL-OK") == 9
    assert "DEVICE-MATRIX-OK" in out
