"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.optim import Adafactor, Adam, AdamW, SGD, cosine_warmup

KEY = jax.random.PRNGKey(0)
A = jax.random.normal(KEY, (12, 12))
A = A @ A.T / 12 + jnp.eye(12)       # SPD quadratic


def _run(opt, steps=200, lr=0.05):
    params = {"x": jax.random.normal(jax.random.fold_in(KEY, 1), (12,))}

    @jax.jit
    def step(p, s):
        def loss(pp):
            return 0.5 * pp["x"] @ A @ pp["x"]
        l, g = jax.value_and_grad(loss)(p)
        p2, s2 = opt.step(p, g, s, lr)
        return p2, s2, l

    state = opt.init(params)
    l0 = None
    for _ in range(steps):
        params, state, l = step(params, state)
        l0 = l if l0 is None else l0
    return float(l0), float(l)


@pytest.mark.parametrize("opt,lr", [
    (SGD(momentum=0.0), 0.1), (SGD(momentum=0.9), 0.05),
    (SGD(momentum=0.9, nesterov=True), 0.05),
    (Adam(), 0.05), (AdamW(0.001), 0.05), (Adafactor(), 0.2),
])
def test_optimizers_descend_quadratic(opt, lr):
    l0, lT = _run(opt, lr=lr)
    assert lT < l0 * 0.05, type(opt).__name__


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = Adafactor().init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (32,)
    full = 64 * 32
    fact = 64 + 32
    assert fact < full / 10


def test_cosine_warmup_shape():
    s = cosine_warmup(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 0.2
    assert float(s(55)) < float(s(20))
