"""Communication-scheduling model tests (TicTac/Bösen, survey §3.3.3(3))."""
import pytest

from repro.core.comm_scheduler import (LayerCost, LinkModel, bucketize,
                                       random_order, schedule_no_overlap,
                                       schedule_overlap, tictac_order)

LINK = LinkModel(alpha_s=1e-5, beta_Bps=50e9)


def _layers(n=24):
    # transformer-ish: equal compute, equal grads
    return [LayerCost(f"l{i}", back_compute_s=2e-3, grad_bytes=50e6)
            for i in range(n)]


def test_overlap_beats_no_overlap():
    ls = _layers()
    t_no = schedule_no_overlap(ls, LINK)
    t_tictac = schedule_overlap(ls, LINK, tictac_order(ls))
    assert t_tictac < t_no


def test_tictac_no_worse_than_random():
    ls = _layers()
    t_tictac = schedule_overlap(ls, LINK, tictac_order(ls))
    t_rand = min(schedule_overlap(ls, LINK, random_order(ls, s))
                 for s in range(5))
    assert t_tictac <= t_rand + 1e-12


def test_bucketing_amortizes_latency():
    # latency-dominated regime: many tiny gradients
    ls = [LayerCost(f"l{i}", 1e-4, 1e4) for i in range(200)]
    slow_link = LinkModel(alpha_s=1e-3, beta_Bps=50e9)
    t_unbucketed = schedule_overlap(ls, slow_link, tictac_order(ls))
    bs = bucketize(ls, bucket_bytes=5e5)
    t_bucketed = schedule_overlap(bs, slow_link, tictac_order(bs))
    assert t_bucketed < t_unbucketed
    assert len(bs) < len(ls)


def test_bucketize_preserves_totals():
    ls = _layers(10)
    bs = bucketize(ls, bucket_bytes=120e6)
    assert abs(sum(b.grad_bytes for b in bs)
               - sum(l.grad_bytes for l in ls)) < 1
    assert abs(sum(b.back_compute_s for b in bs)
               - sum(l.back_compute_s for l in ls)) < 1e-9
