"""Observability plane: trace recorder semantics, dual-clock determinism,
metrics registry aggregation, and the instrumented serve engine
(docs/observability.md)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import Compressor
from repro.comm.plan import CommPlan
from repro.obs.metrics import MetricsRegistry, percentile
from repro.obs.trace import (NullRecorder, TraceRecorder, canonical_bytes,
                             emit_sched_trace, find_spans, get_recorder,
                             set_recorder, strip_wall, tracing,
                             validate_trace)


# ------------------------------------------------------------- recorder
def test_default_recorder_is_noop():
    rec = get_recorder()
    assert isinstance(rec, NullRecorder)
    assert rec.enabled is False
    # the disabled hot path: span/instant/counter are all no-ops and the
    # shared null span is reused (no per-call allocation)
    assert rec.span("x", pid="p") is rec.span("y", tid="t")
    rec.begin("a")
    rec.end()
    rec.instant("i", foo=1)
    rec.counter("c", {"v": 1.0})


def test_tracing_installs_and_restores(tmp_path):
    before = get_recorder()
    path = tmp_path / "t.json"
    with tracing(str(path)) as rec:
        assert get_recorder() is rec
        with rec.span("outer", pid="p", tid="t", clock=("train_step", 0)):
            rec.instant("mark", pid="p", tid="t")
    assert get_recorder() is before
    trace = json.loads(path.read_bytes())
    stats = validate_trace(trace)
    assert stats["spans"] == 1 and stats["instants"] == 1


def test_span_nesting_and_validation():
    rec = TraceRecorder()
    with rec.span("step", pid="train", tid="loop"):
        with rec.span("compute", pid="train", tid="loop"):
            pass
        with rec.span("exchange", pid="train", tid="loop"):
            rec.instant("hop", pid="train", tid="loop")
    stats = validate_trace(rec.to_chrome())
    assert stats["max_depth"] == 2
    assert stats["spans"] == 3
    # unmatched end is rejected at record time
    with pytest.raises(ValueError):
        rec.end(pid="train", tid="loop")


def test_dual_clock_and_wall_strip():
    rec = TraceRecorder()
    rec.begin("step", pid="train", tid="loop", clock=("train_step", 7))
    rec.end(pid="train", tid="loop")
    tr = rec.to_chrome()
    b = find_spans(tr, "step")[0]
    assert b["args"]["clock_domain"] == "train_step"
    assert b["args"]["clock_t"] == 7
    assert "wall_s" in b["args"]
    stripped = strip_wall(tr)
    assert all("wall_s" not in ev["args"]
               for ev in stripped["traceEvents"])
    # ...and include_wall=False serializes identically to the strip
    assert (canonical_bytes(strip_wall(json.loads(rec.to_bytes()))) ==
            rec.to_bytes(include_wall=False))


def test_trace_determinism_on_virtual_clock():
    """Two identical event sequences differ only in wall time — the
    virtual tick timeline is byte-identical after strip_wall."""
    def run():
        rec = TraceRecorder()
        for t in range(3):
            with rec.span("step", pid="train", tid="loop",
                          clock=("train_step", t), step=t):
                rec.counter("wire_bytes", {"cumulative": 10.0 * t},
                            pid="train", clock=("train_step", t))
        return rec.to_chrome()
    a, b = run(), run()
    assert a != b                      # wall clocks differ...
    assert (canonical_bytes(strip_wall(a)) ==
            canonical_bytes(strip_wall(b)))     # ...nothing else does


# ------------------------------------------------------------ comm plan
def test_commplan_emit_trace_matches_accounting():
    """The per-hop model sums to the plan's measured per-step bytes
    (CommPlan.plan is pure host — no devices needed)."""
    params = {"a": jnp.zeros((64, 8)), "b": jnp.zeros((130,))}
    for topo in ("ring", "tree", "butterfly", "fully_connected"):
        plan = CommPlan.plan(params, axis="w", n=4, topology=topo,
                             compressor=Compressor("onebit"),
                             wire="measured", bucket_mb=1e-4)
        per_bucket = [sum(x for _, x in plan.hop_model(b))
                      for b in range(len(plan.buckets))]
        assert int(sum(per_bucket)) == plan.measured_step_tx_bytes()
        rec = TraceRecorder()
        plan.emit_trace(rec, clock=("train_step", 0))
        tr = rec.to_chrome()
        stats = validate_trace(tr)
        assert len(find_spans(tr, "exchange")) == 1
        bucket_spans = [n for n in stats["names"]
                        if n.startswith("bucket")]
        assert len(bucket_spans) == len(plan.buckets)
        hop_bytes = sum(ev["args"]["tx_bytes"]
                        for ev in tr["traceEvents"]
                        if ev.get("ph") == "i" and ev["name"] == "hop")
        assert hop_bytes == pytest.approx(sum(per_bucket), abs=0.01)


def test_commplan_ps_hop_model():
    params = {"a": jnp.zeros((64, 8))}
    plan = CommPlan.plan(params, axis="w", n=4, topology="ring",
                         compressor=Compressor("onebit"), wire="measured",
                         bucket_mb=1.0)
    hops = plan.hop_model(0, arch="ps")
    assert [k for k, _ in hops] == ["rs"] * 3 + ["ag"] * 3
    assert int(sum(x for _, x in hops)) == plan.measured_step_tx_bytes("ps")


# ---------------------------------------------------------- sched bridge
def test_emit_sched_trace_spans_and_truncation():
    from repro.sched.simulator import TraceEvent
    rec = TraceRecorder()
    emit_sched_trace(rec, [
        TraceEvent(0.0, 1, "start", 2),
        TraceEvent(5.0, 1, "suspend", 2),
        TraceEvent(6.0, 1, "resume", 4),
        TraceEvent(9.0, 1, "finish", 4),
        TraceEvent(2.0, 2, "start", 1),      # never finishes
    ])
    tr = rec.to_chrome()
    stats = validate_trace(tr)               # truncated job was closed
    assert stats["spans"] == 3
    assert stats["instants"] == 5
    last = [ev for ev in tr["traceEvents"] if ev.get("ph") == "E"][-1]
    assert last["args"].get("truncated") is True


# -------------------------------------------------------------- metrics
def test_percentile_edges():
    assert np.isnan(percentile([], 50))
    assert percentile([3.0], 0) == 3.0
    assert percentile([3.0], 100) == 3.0
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == 3.0          # nearest rank of 4 samples
    with pytest.raises(ValueError):
        percentile(xs, 101)
    with pytest.raises(ValueError):
        percentile(xs, -1)


def test_percentile_reexport_is_shared():
    from repro.serve import request as req
    assert req.percentile is percentile


def test_metrics_registry_aggregation(tmp_path):
    m = MetricsRegistry()
    m.counter("steps").inc()
    m.counter("steps").inc(4)
    m.gauge("workers").set(8)
    for v in [1.0, 2.0, 3.0, 10.0]:
        m.histogram("lat").observe(v)
    snap = m.snapshot()
    assert snap["steps"]["value"] == 5
    assert snap["workers"]["value"] == 8
    assert snap["lat"]["count"] == 4
    assert snap["lat"]["sum"] == 16.0
    assert snap["lat"]["p50"] == 3.0          # nearest rank of 4 samples
    # same name, different kind -> loud failure
    with pytest.raises(ValueError):
        m.gauge("steps")
    with pytest.raises(ValueError):
        m.counter("steps").inc(-1)
    path = tmp_path / "m.jsonl"
    m.export_jsonl(str(path), run="r0")
    rows = [json.loads(x) for x in path.read_text().splitlines()]
    assert {r["metric"] for r in rows} == {"steps", "workers", "lat"}
    assert all(r["run"] == "r0" for r in rows)


# ----------------------------------------------------------- serve trace
def _serve_episode(num_pages=None):
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.request import Request
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(1, cfg.vocab_size, size=(4, 5))
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=6) for i in range(4)]
    eng = ServeEngine(model, params, ServeConfig(
        slots=4, max_len=16, page_size=4, num_pages=num_pages,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32))
    with tracing() as rec:
        m = eng.run(reqs)
    return rec.to_chrome(), m


def test_serve_trace_pool_exhaustion_stalls():
    """An undersized page pool shows up on the trace: stall instants plus
    full queued->prefill->decode lifecycles once pages free up."""
    tr, m = _serve_episode(num_pages=6)
    assert m["admission_stalls"] > 0
    stats = validate_trace(tr)
    stalls = [ev for ev in tr["traceEvents"]
              if ev.get("ph") == "i" and ev["name"] == "admission_stall"]
    assert len(stalls) > 0
    assert all(ev["args"]["free_pages"] >= 0 for ev in stalls)
    assert len(find_spans(tr, "queued")) == 4
    assert len(find_spans(tr, "prefill")) == 4
    assert len(find_spans(tr, "decode")) == 4
    # the kv_pages counter track tops out at the pool capacity
    kv = [ev for ev in tr["traceEvents"]
          if ev.get("ph") == "C" and ev["name"] == "kv_pages"]
    assert kv and all(ev["args"]["used"] + ev["args"]["free"] == 5
                      for ev in kv)           # 6 pages - 1 reserved
    assert "admission_stall" in stats["names"]


def test_serve_untraced_records_nothing():
    """With no recorder installed the engine leaves no lifecycle state."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.request import Request
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2)]
    eng = ServeEngine(model, params, ServeConfig(
        slots=1, max_len=8, page_size=4,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32))
    assert isinstance(get_recorder(), NullRecorder)
    eng.run(reqs)
    assert eng._traced_rids == set()


def test_set_recorder_restores_null():
    rec = TraceRecorder()
    prev = set_recorder(rec)
    try:
        assert get_recorder() is rec
    finally:
        set_recorder(prev)
    assert isinstance(get_recorder(), NullRecorder)


# ------------------------------------------------- validation edge cases
def test_validate_trace_counter_only():
    """A trace holding only counter samples is structurally valid."""
    rec = TraceRecorder()
    for t in range(3):
        rec.counter("wire_bytes", {"cumulative": 10.0 * t}, pid="train",
                    clock=("train_step", t))
    stats = validate_trace(rec.to_chrome())
    assert stats["spans"] == 0 and stats["instants"] == 0
    assert stats["counters"] == 3
    assert stats["max_depth"] == 0
    assert stats["errors"] == []
    assert stats["names"] == ["wire_bytes"]


def test_validate_trace_lax_reports_not_raises():
    """strict=False collects structural problems into errors; strict=True
    raises on the first one.  Both see the same damage."""
    bad = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 0, "pid": 1, "tid": 1, "args": {}},
        {"name": "a", "ph": "E", "ts": 5, "pid": 1, "tid": 1, "args": {}},
        # ts regression
        {"name": "x", "ph": "i", "ts": 2, "pid": 1, "tid": 1, "args": {}},
        # E without any open B on its track
        {"name": "z", "ph": "E", "ts": 6, "pid": 1, "tid": 2, "args": {}},
        # never closed
        {"name": "open", "ph": "B", "ts": 7, "pid": 1, "tid": 1,
         "args": {}},
    ]}
    with pytest.raises(ValueError):
        validate_trace(bad)
    stats = validate_trace(bad, strict=False)
    assert len(stats["errors"]) == 3
    assert any("backwards" in e for e in stats["errors"])
    assert any("E without B" in e for e in stats["errors"])
    assert any("unclosed" in e for e in stats["errors"])
    # counting still completed despite the damage
    assert stats["spans"] == 1 and stats["instants"] == 1


def test_validate_trace_not_a_trace():
    with pytest.raises(ValueError):
        validate_trace({"events": []})
    stats = validate_trace({"events": []}, strict=False)
    assert stats["errors"] and stats["events"] == 0


def test_trace_save_load_byte_roundtrip(tmp_path):
    """save -> load_trace -> canonical_bytes reproduces the exact bytes,
    and stripping wall from a loaded wall-ful trace matches the direct
    include_wall=False serialization."""
    from repro.obs.trace import load_trace
    rec = TraceRecorder()
    with rec.span("step", pid="train", tid="loop",
                  clock=("train_step", 0)):
        rec.instant("mark", pid="train", tid="loop")
    p = tmp_path / "t.json"
    rec.save(str(p), include_wall=False)
    loaded = load_trace(str(p))
    assert canonical_bytes(loaded) == rec.to_bytes(include_wall=False)
    # wall-crossing round trip: strip after reload, same bytes again
    p2 = tmp_path / "t_wall.json"
    rec.save(str(p2), include_wall=True)
    assert (canonical_bytes(strip_wall(load_trace(str(p2)))) ==
            rec.to_bytes(include_wall=False))


# ----------------------------------------------------- bounded histogram
def test_histogram_exact_below_cap():
    from repro.obs.metrics import Histogram
    h = Histogram(max_samples=10)
    for v in [5.0, 1.0, 3.0]:
        h.observe(v)
    assert h.count == 3 and h.sum == 9.0
    assert h.percentile(50) == 3.0            # exact: all samples held
    snap = h.snapshot()
    assert "retained" not in snap             # nothing was dropped
    assert snap["min"] == 1.0 and snap["max"] == 5.0


def test_histogram_bounded_above_cap():
    from repro.obs.metrics import Histogram
    h = Histogram(max_samples=8)
    for v in range(100):
        h.observe(float(v))
    assert len(h.samples) == 8                # memory stays bounded
    assert h.count == 100                     # aggregates stay exact
    assert h.sum == float(sum(range(100)))
    snap = h.snapshot()
    assert snap["min"] == 0.0 and snap["max"] == 99.0
    assert snap["mean"] == pytest.approx(49.5)
    assert snap["retained"] == 8.0
    assert all(s in [float(v) for v in range(100)] for s in h.samples)


def test_histogram_reservoir_deterministic():
    from repro.obs.metrics import Histogram

    def fill():
        h = Histogram(max_samples=16)
        for v in range(500):
            h.observe(float(v * 7 % 101))
        return h
    a, b = fill(), fill()
    assert a.samples == b.samples             # fixed-seed PRNG
    assert a.snapshot() == b.snapshot()


def test_histogram_cap_validation_and_registry():
    from repro.obs.metrics import Histogram
    with pytest.raises(ValueError):
        Histogram(max_samples=0)
    m = MetricsRegistry()
    h = m.histogram("lat", max_samples=4)
    assert h.max_samples == 4
    assert m.histogram("lat") is h            # get-or-create keeps the cap
