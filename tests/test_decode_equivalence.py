"""Property: token-by-token decode through the cache reproduces the full
teacher-forced forward (the KV-cache/state invariant), for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model

B, S = 2, 12

CASES = ["tinyllama-1.1b", "stablelm-1.6b", "command-r-35b", "llama3.2-3b",
         "qwen2-vl-7b", "recurrentgemma-9b", "rwkv6-7b",
         "deepseek-v2-lite-16b", "kimi-k2-1t-a32b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        # no-drop capacity so dispatch is identical between modes
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None],
                                           (B, 3, S))
    full, _, _ = model.forward(params, toks, compute_dtype=jnp.float32, **kw)
    caches = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t,
                                       compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-4, arch


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper-large-v3").reduced()
    from repro.models import whisper as W
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    frames = jax.random.normal(key, (B, cfg.max_source_positions, cfg.d_model))
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc = W.encode(params, cfg, frames, compute_dtype=jnp.float32)
    full = W.decode_train(params, cfg, toks, enc, compute_dtype=jnp.float32)
    caches = model.init_cache(B, S, dtype=jnp.float32)
    caches["cross"] = W.build_cross_cache(params, cfg, enc, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t,
                                       compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-4


def test_sliding_window_decode_matches_within_window():
    """With a window override, decode logits match full-cache decode while
    the context still fits the window (sub-quadratic serving invariant)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    W_ = 8
    toks = jax.random.randint(key, (B, W_), 0, cfg.vocab_size)
    c_full = model.init_cache(B, W_, dtype=jnp.float32)
    c_win = model.init_cache(B, W_, dtype=jnp.float32, window_override=W_)
    for t in range(W_):
        lf, c_full = model.decode_step(params, c_full, toks[:, t:t + 1], t,
                                       compute_dtype=jnp.float32)
        lw, c_win = model.decode_step(params, c_win, toks[:, t:t + 1], t,
                                      compute_dtype=jnp.float32,
                                      window_override=W_)
        assert float(jnp.max(jnp.abs(lf - lw))) < 2e-4, t
