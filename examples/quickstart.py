"""Quickstart: build an assigned architecture, train it on the synthetic
pipeline, checkpoint + register it, and decode from it — the whole public
API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse
import os
import tempfile

import jax

from repro.checkpoint import ModelRegistry, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.compression import Compressor
from repro.core.precision import PrecisionPolicy
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.optim import Adam
from repro.serve import generate
from repro.train import TrainState, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    # 1. model (reduced variant of the assigned config, CPU-sized)
    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. data pipeline (deterministic synthetic LM stream)
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)
    batches = make_lm_batches(data)

    # 3. trainer: Adam + bf16 compute + 1-bit gradient compression
    opt = Adam()
    comp = Compressor("onebit")
    step = make_train_step(model.loss_fn, opt,
                           precision=PrecisionPolicy(compute_dtype="float32"),
                           compressor=comp)
    state = TrainState.create(params, opt, comp)
    state, hist = train_loop(step, state, lambda t: batches(t, 0),
                             args.steps, log_every=args.steps // 5)
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({hist[-1]['wire_bytes']:.0f} wire B/step with 1-bit EF)")

    # 4. checkpoint + registry (ModelDB-style)
    root = tempfile.mkdtemp(prefix="repro-quickstart-")
    ck = os.path.join(root, "ckpt")
    save_checkpoint(ck, state["params"], step=args.steps)
    reg = ModelRegistry(os.path.join(root, "registry"))
    mid = reg.register("quickstart", ck, arch=cfg.name,
                       metrics={"loss": hist[-1]["loss"]})
    print("registered:", mid)

    # 5. reload + decode
    restored, _ = load_checkpoint(ck, state["params"])
    prompt = jax.numpy.asarray([[1, 2, 3, 4]])
    out = generate(model, restored, prompt, max_new_tokens=12)
    print("decoded:", out[0].tolist())


if __name__ == "__main__":
    main()
