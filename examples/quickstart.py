"""Quickstart: build an assigned architecture, train it on the synthetic
pipeline, checkpoint + register it, and decode from it — the whole public
API in ~80 lines.

The parallel-training strategy is one declarative spec string
(``Strategy.parse``; grammar and matrix in docs/strategies.md):

  PYTHONPATH=src python examples/quickstart.py                # 1-bit EF BSP
  PYTHONPATH=src python examples/quickstart.py --strategy ssp:2/ps/onebit@4

The default single-worker BSP spec trains through ``make_train_step``
(Adam); any other cell trains through the Strategy engine — on this
single-device process the ``auto`` backend picks the deterministic
simulator, so multi-worker specs need no device re-exec here.
"""
import argparse
import os
import tempfile

import jax

from repro.checkpoint import ModelRegistry, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.precision import PrecisionPolicy
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.optim import Adam
from repro.serve import generate
from repro.train import Strategy, Trainer, TrainState, make_train_step, \
    train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--strategy", default="bsp/allreduce/onebit@1",
                    help="sync[:staleness]/arch/comp[:density]@workers")
    args = ap.parse_args()
    # like train_100m_e2e: a spec without "@N" means 1 worker here, not
    # Strategy's default of 4 — keeps --strategy bsp/allreduce/dgc on the
    # single-worker Adam path
    strat = Strategy.parse(args.strategy, lr=0.05, workers=1)

    # 1. model (reduced variant of the assigned config, CPU-sized)
    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. data pipeline (deterministic synthetic LM stream)
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)
    batches = make_lm_batches(data)

    # 3. trainer, configured by the strategy spec
    comp = strat.compressor
    if strat.workers == 1 and strat.sync == "bsp" and \
            strat.arch == "allreduce":
        # single-worker BSP: the jitted Adam train step
        step = make_train_step(
            model.loss_fn, Adam(),
            precision=PrecisionPolicy(compute_dtype="float32"),
            compressor=comp)
        state = TrainState.create(params, Adam(), comp)
        state, hist = train_loop(step, state, lambda t: batches(t, 0),
                                 args.steps,
                                 log_every=max(1, args.steps // 5))
        trained = state["params"]
        print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"({hist[-1]['wire_bytes']:.0f} wire B/step, "
              f"{comp.method} compression)")
    else:
        # any other cell: the declarative Strategy engine (SGD)
        def grad_fn(p, batch):
            (loss, _), g = jax.value_and_grad(
                lambda pp: model.loss_fn(pp, batch,
                                         compute_dtype=jax.numpy.float32),
                has_aux=True)(p)
            return loss, g

        trained, hist, mets = Trainer(strat).fit(
            grad_fn, params, batches, args.steps)
        print(f"{mets['spec']} on {mets['backend']} backend: loss "
              f"{hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"({mets['wire_bytes']} wire B total)")

    # 4. checkpoint + registry (ModelDB-style)
    root = tempfile.mkdtemp(prefix="repro-quickstart-")
    ck = os.path.join(root, "ckpt")
    save_checkpoint(ck, trained, step=args.steps)
    reg = ModelRegistry(os.path.join(root, "registry"))
    mid = reg.register("quickstart", ck, arch=cfg.name,
                       metrics={"loss": hist[-1]["loss"]})
    print("registered:", mid)

    # 5. reload + decode
    restored, _ = load_checkpoint(ck, trained)
    prompt = jax.numpy.asarray([[1, 2, 3, 4]])
    out = generate(model, restored, prompt, max_new_tokens=12)
    print("decoded:", out[0].tolist())


if __name__ == "__main__":
    main()
