"""Long-context serving on the continuous-batching engine: compares a
dense arch's full KV cache against a sliding-window cache and the
constant-state SSMs (the long_500k configuration at CPU scale), then
shows the paged pool serving the same tokens from a fraction of the
full-cache footprint.

  PYTHONPATH=src python examples/serve_longcontext.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.cache import cache_bytes
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

B, PROMPT, NEW = 2, 24, 24


def run(arch: str, window: int = 0, page_size: int = 0):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = rng.randint(1, cfg.vocab_size, size=(B, PROMPT))
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=NEW) for i in range(B)]
    eng = ServeEngine(model, params, ServeConfig(
        slots=B, max_len=PROMPT + NEW, page_size=page_size,
        window_override=window,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32))
    m = eng.run(reqs)
    nbytes = cache_bytes(eng.kv.store)
    label = arch + (f" (window={window})" if window else "") \
        + (f" (pages={page_size})" if page_size else "")
    print(f"{label:42s} {m['wall_s']:5.1f}s  cache={nbytes / 1e6:7.2f} MB  "
          f"sample={reqs[0].output[:8]}")
    return nbytes, [r.output for r in reqs]


def main():
    print("arch (decode mode)                          time   cache")
    full, toks_full = run("tinyllama-1.1b")           # full KV cache
    swa, _ = run("tinyllama-1.1b", window=8)          # sliding window
    ssm, _ = run("rwkv6-7b")                          # constant state
    hyb, _ = run("recurrentgemma-9b")                 # RG-LRU + local window
    _, toks_paged = run("tinyllama-1.1b", page_size=8)   # paged pool
    assert swa <= full and ssm < full
    assert toks_paged == toks_full, "paged layout changed tokens"
    print("\nsliding-window and SSM caches are context-length-independent —"
          "\nthe property that makes long_500k decode feasible (DESIGN.md §3)."
          "\nThe paged pool serves the SAME tokens as the full cache from"
          "\nblock-granular storage (docs/serving.md).")


if __name__ == "__main__":
    main()
