"""Batched serving with sub-quadratic long-context decode: compares a
dense arch with a sliding-window cache against the constant-state SSM
(the long_500k configuration at CPU scale).

  PYTHONPATH=src python examples/serve_longcontext.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import generate


def run(arch: str, window: int = 0, prompt_len: int = 24, max_new: int = 24):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompt, max_new, window_override=window)
    dt = time.time() - t0
    # cache footprint per token of context
    caches = model.init_cache(2, prompt_len + max_new, dtype=jnp.bfloat16,
                              window_override=window)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(caches))
    label = f"{arch}" + (f" (window={window})" if window else "")
    print(f"{label:42s} {dt:5.1f}s  cache={cache_bytes / 1e6:7.2f} MB  "
          f"sample={out[0, prompt_len:prompt_len + 8].tolist()}")
    return cache_bytes


def main():
    print("arch (decode mode)                          time   cache")
    full = run("tinyllama-1.1b")                  # full KV cache
    swa = run("tinyllama-1.1b", window=8)         # sliding window
    ssm = run("rwkv6-7b")                         # constant state
    hyb = run("recurrentgemma-9b")                # RG-LRU + local window
    assert swa <= full and ssm < full
    print("\nsliding-window and SSM caches are context-length-independent —"
          "\nthe property that makes long_500k decode feasible (DESIGN.md §3).")


if __name__ == "__main__":
    main()
