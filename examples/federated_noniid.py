"""Federated learning (survey §3.3.1(3)): FedAvg on IID vs Dirichlet
non-IID client splits, reproducing the degradation Nilsson et al. [130]
report for the non-IID regime.

  PYTHONPATH=src python examples/federated_noniid.py
"""
import jax
import jax.numpy as jnp

from repro.core.federated import FedConfig, run_fedavg
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  label_skew, make_classification_data)

N, DIM, CLASSES, CLIENTS = 1500, 16, 8, 10


def main():
    X, y = make_classification_data(N, DIM, CLASSES, seed=0)

    def grad_fn(params, batch):
        def loss(p):
            h = jnp.tanh(batch["X"] @ p["w1"])
            logits = h @ p["w2"]
            logz = jax.nn.logsumexp(logits, -1)
            ll = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
            return jnp.mean(logz - ll)
        return jax.value_and_grad(loss)(params)

    def clients_for(parts):
        import numpy as np
        fns = []
        for idx in parts:
            def fn(step, idx=idx):
                rng = np.random.RandomState(step)
                sel = idx[rng.randint(0, len(idx), size=min(32, len(idx)))]
                return {"X": jnp.asarray(X[sel]), "y": jnp.asarray(y[sel])}
            fns.append(fn)
        return fns

    cfg = FedConfig(num_clients=CLIENTS, clients_per_round=5, local_steps=4,
                    local_lr=0.1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    p0 = {"w1": jax.random.normal(k1, (DIM, 32)) * 0.2,
          "w2": jax.random.normal(k2, (32, CLASSES)) * 0.2}

    for name, parts in [
            ("iid", iid_partition(N, CLIENTS, seed=0)),
            ("non-iid (alpha=0.1)", dirichlet_partition(y, CLIENTS, 0.1,
                                                        seed=0))]:
        _, hist = run_fedavg(p0, clients_for(parts), grad_fn, cfg, 15)
        print(f"{name:22s} skew={label_skew(parts, y):.2f}  "
              f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
