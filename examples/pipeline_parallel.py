"""Pipeline parallelism (GPipe, survey §3.2.3) on 4 virtual devices: a
4-stage pipeline over micro-batches, showing the bubble fraction shrink as
micro-batch count grows.  Re-execs itself with virtual devices.

  PYTHONPATH=src python examples/pipeline_parallel.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core.collectives import shard_map                   # noqa: E402
from repro.core.pipeline import bubble_fraction, gpipe_forward  # noqa: E402


def main():
    n_stages = 4
    d = 64
    mesh = Mesh(np.array(jax.devices()[:n_stages]), ("stage",))
    key = jax.random.PRNGKey(0)
    stage_w = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    for n_micro in (1, 4, 16):
        xm = jax.random.normal(key, (n_micro, 8, d))
        f = shard_map(
            lambda w, x: gpipe_forward(stage_fn, w[0], x, "stage")[None],
            mesh=mesh, in_specs=(P("stage"), P(None)), out_specs=P("stage"),
            check_vma=False)
        out = f(stage_w, xm).sum(0)
        # sequential reference
        seq = xm
        for i in range(n_stages):
            seq = jnp.tanh(seq @ stage_w[i])
        err = float(jnp.max(jnp.abs(out - seq)))
        print(f"micro-batches={n_micro:3d}  bubble="
              f"{bubble_fraction(n_stages, n_micro):.2f}  max_err={err:.2e}")
    print("\npipeline == sequential; bubble -> 0 as micro-batches grow "
          "(GPipe Fig. 2).")


if __name__ == "__main__":
    main()
