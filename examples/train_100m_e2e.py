"""End-to-end driver (deliverable b): train a ~100M-param llama-family
model for a few hundred steps on the synthetic pipeline, with cosine
schedule, checkpointing every N steps, and a final registry entry.

  PYTHONPATH=src python examples/train_100m_e2e.py --steps 300
(CPU: ~1-4 s/step at the default batch; use --steps 30 for a quick pass.)

The parallel-training strategy is one declarative spec string
(``Strategy.parse``; see docs/strategies.md for the grammar and matrix):

  --strategy bsp/allreduce/onebit@8   8-worker BSP, TicTac-bucketed ring
                                      allreduce, 1-bit EF compression,
                                      AdamW + cosine schedule under
                                      shard_map (the full trainer path)
  --strategy bsp/ps/dgc:0.05@8        centralized ZeRO-style PS arch
  --strategy ssp:3/allreduce/onebit@8 bounded-staleness on devices
                                      (Strategy engine path, SGD)
  --strategy bsp/ps/none@4:d4.z3.adamw  ZeRO-3-sharded AdamW over the
                                      data axis (hybrid engine; the
                                      tensor/stage mesh axes need a
                                      StagedModel — docs/hybrid.md)

Multi-worker specs re-exec with that many virtual host devices.

``--failure-plan "crash:w1@5,resize:4@10"`` demonstrates elastic
fault-tolerant training end to end: the run snapshots through
repro.checkpoint, loses worker 1 before step 5, recovers from the latest
checkpoint, reshards to the survivors, and grows back to 4 workers at
step 10 — all in one process (docs/elasticity.md):

  PYTHONPATH=src python examples/train_100m_e2e.py --steps 30 \
      --strategy ssp:2/allreduce/onebit@4 --failure-plan crash:w1@5 \
      --checkpoint-every 5
"""
import argparse
import dataclasses
import json
import os
import sys
import time


def _spec_workers(spec: str) -> int:
    """Worker count from a strategy spec string, pre-jax-import (the full
    parse lives in repro.train.strategy, which imports jax).  The worker
    segment may carry a mesh suffix: ``@8:d2.t2.s2`` (docs/hybrid.md)."""
    if "@" not in spec:
        return 1
    return int(spec.rsplit("@", 1)[1].split(":", 1)[0])


def _maybe_reexec_with_devices():
    """Virtual host devices must be configured before jax import."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--strategy", default="bsp/allreduce/none@1")
    n = _spec_workers(ap.parse_known_args()[0].strategy)
    if n > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
        os.execv(sys.executable, [sys.executable] + sys.argv)


_maybe_reexec_with_devices()

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402
from jax.sharding import Mesh                     # noqa: E402

from repro.checkpoint import ModelRegistry, save_checkpoint   # noqa: E402
from repro.configs import get_config              # noqa: E402
from repro.core.precision import PrecisionPolicy  # noqa: E402
from repro.data import LMDataConfig, make_lm_batches  # noqa: E402
from repro.models import build_model              # noqa: E402
from repro.optim import AdamW                     # noqa: E402
from repro.optim.schedule import cosine_warmup    # noqa: E402
from repro.train import (Strategy, Trainer, TrainState,  # noqa: E402
                         make_train_step, train_loop,
                         make_bucketed_allreduce, make_sharded_train_step)
from repro.train.data_parallel import AXIS        # noqa: E402


def _fit_with_optimizer(strat, model, params, batches, args):
    """The full trainer path (AdamW + cosine + checkpointable TrainState)
    for bsp/allreduce specs — compression and worker count come from the
    strategy; K>1 lifts the step under shard_map."""
    opt = AdamW(0.01)
    compressor = strat.compressor
    K = strat.workers
    if K > 1:
        reduce_fn = make_bucketed_allreduce(
            params, topology=strat.topology, bucket_mb=strat.bucket_mb,
            order=strat.order)
        step = make_train_step(
            model.loss_fn, opt, cosine_warmup(args.lr, 20, args.steps),
            precision=PrecisionPolicy(compute_dtype="float32"),
            compressor=compressor, reduce_fn=reduce_fn)
        state = TrainState.create(params, opt, compressor)
        if state["ef"] is not None:     # per-worker error-feedback state
            state["ef"] = jax.tree.map(
                lambda x: jnp.zeros((K,) + x.shape, x.dtype), state["ef"])
        if len(jax.devices()) < K:      # e.g. caller pre-set XLA_FLAGS low
            raise SystemExit(
                f"need {K} devices, have {len(jax.devices())}; unset "
                "XLA_FLAGS or set --xla_force_host_platform_device_count")
        mesh = Mesh(np.array(jax.devices()[:K]), (AXIS,))
        sharded = make_sharded_train_step(step, mesh,
                                          compressed=state["ef"] is not None)
        print(f"data-parallel: {strat.spec()}, "
              f"{len(reduce_fn.fused_layers)} buckets ({strat.order} order)")

        def stacked_batch(t):
            per = [batches(t, w) for w in range(K)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

        state, hist = train_loop(sharded, state, stacked_batch,
                                 args.steps, log_every=10, jit=False)
    else:
        step = make_train_step(
            model.loss_fn, opt, cosine_warmup(args.lr, 20, args.steps),
            precision=PrecisionPolicy(compute_dtype="float32"),
            compressor=compressor)
        state = TrainState.create(params, opt, compressor)
        state, hist = train_loop(step, state, lambda t: batches(t, 0),
                                 args.steps, log_every=10)
    return state["params"], hist


def _fit_with_strategy_engine(strat, model, params, batches, args):
    """Every other cell (ssp/asp staleness replay, arch=ps, sma) goes
    through the Strategy engine (SGD at --engine-lr) via Trainer.fit.
    With --failure-plan the run goes through the elastic trainer: the
    engine is snapshotted every --checkpoint-every steps and survives the
    plan's crashes/resizes/stragglers in process (docs/elasticity.md)."""
    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    strat = dataclasses.replace(strat, lr=args.engine_lr)
    trainer = Trainer(strat)
    if args.failure_plan:
        params, hist, mets = trainer.fit(
            grad_fn, params, batches, args.steps, plan=args.failure_plan,
            checkpoint_dir=os.path.join(args.out, "elastic_ckpts"),
            checkpoint_every=args.checkpoint_every)
        for r in mets["recoveries"]:
            print(f"  {r['kind']} at step {r['at']}: restored step "
                  f"{r['restored_step']} ({r['lost_steps']} steps lost, "
                  f"{r['wall_s']:.2f}s), now {r['workers']} workers")
        print(f"elastic: {len(mets['recoveries'])} recoveries, "
              f"{mets['resizes']} resizes, "
              f"{mets['executed_steps']} steps executed for "
              f"{args.steps} committed "
              f"(goodput {args.steps / mets['executed_steps']:.2f}), "
              f"{mets.get('dropped_updates', 0)} straggler pushes dropped")
    else:
        params, hist, mets = trainer.fit(grad_fn, params, batches,
                                         args.steps)
    print(f"strategy engine: {mets['spec']} on {mets['backend']} backend, "
          f"{mets['wire_bytes']} wire B total")
    return params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--strategy", default="bsp/allreduce/none@1",
                    help="parallel-training spec: "
                         "sync[:staleness]/arch/comp[:density]@workers, "
                         "e.g. bsp/allreduce/onebit@8 (docs/strategies.md)")
    ap.add_argument("--engine-lr", type=float, default=0.05,
                    help="SGD lr for non-bsp/allreduce cells, which train "
                         "through the Strategy engine instead of AdamW")
    ap.add_argument("--failure-plan", default="",
                    help="elastic event plan, e.g. 'crash:w1@5,resize:4@10'"
                         " — inject a mid-run crash + recovery (grammar in"
                         " docs/elasticity.md; routes through the Strategy"
                         " engine + elastic trainer)")
    ap.add_argument("--checkpoint-every", type=int, default=10,
                    help="elastic snapshot cadence (global steps)")
    ap.add_argument("--wire", choices=("modeled", "measured"),
                    default="modeled",
                    help="wire accounting / exchange mode (docs/comm.md):"
                         " 'measured' moves the encoded payloads inside"
                         " the collective schedule and counts the planes"
                         " actually exchanged (device cells only)")
    ap.add_argument("--out", default="results/train_100m")
    args = ap.parse_args()
    # workers default must agree with the pre-jax re-exec hook, which
    # reads only the "@N" suffix (no "@N" -> 1 worker, not Strategy's 4)
    strat = Strategy.parse(args.strategy,
                           workers=_spec_workers(args.strategy),
                           wire=args.wire)

    # ~100M-param member of the tinyllama (llama2) family
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name="tinyllama-100m", num_layers=10, d_model=640, d_ff=2560,
        num_heads=10, num_kv_heads=2, head_dim=64, vocab_size=32000)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        batch_size=args.batch_size)
    batches = make_lm_batches(data)

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    if args.failure_plan:
        params, hist = _fit_with_strategy_engine(strat, model, params,
                                                 batches, args)
        trainer_used, lr_used = "strategy-engine-elastic", args.engine_lr
    elif strat.sync == "bsp" and strat.arch == "allreduce" \
            and not strat.is_hybrid and strat.wire == "modeled":
        # measured-wire cells route through the Strategy engine below —
        # the in-schedule codec exchange lives in the engines
        params, hist = _fit_with_optimizer(strat, model, params, batches,
                                           args)
        trainer_used, lr_used = "adamw+cosine", args.lr
    else:
        params, hist = _fit_with_strategy_engine(strat, model, params,
                                                 batches, args)
        trainer_used, lr_used = "strategy-engine-sgd", args.engine_lr
    wall = time.time() - t0
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(hist, f, indent=1)
    ck = os.path.join(args.out, "ckpt_final")
    save_checkpoint(ck, params, step=args.steps)
    reg = ModelRegistry(os.path.join(args.out, "registry"))
    reg.register("tinyllama-100m", ck, arch=cfg.name,
                 hyperparams={"lr": lr_used, "trainer": trainer_used,
                              "steps": args.steps,
                              "strategy": strat.spec()},
                 metrics={"final_loss": hist[-1]["loss"]})
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {wall:.0f}s ({wall / args.steps:.2f}s/step)")


if __name__ == "__main__":
    main()
