"""End-to-end driver (deliverable b): train a ~100M-param llama-family
model for a few hundred steps on the synthetic pipeline, with cosine
schedule, checkpointing every N steps, and a final registry entry.

  PYTHONPATH=src python examples/train_100m_e2e.py --steps 300
(CPU: ~1-4 s/step at the default batch; use --steps 30 for a quick pass.)

Device-sharded data parallelism (PR 1): ``--workers 8`` re-execs with 8
virtual host devices and runs the same train step under shard_map with a
TicTac-ordered bucketed ring allreduce; ``--compress onebit|dgc`` adds
per-worker error-feedback gradient compression on the wire.

  PYTHONPATH=src python examples/train_100m_e2e.py \
      --steps 30 --workers 8 --compress onebit
"""
import argparse
import dataclasses
import json
import os
import sys
import time


def _maybe_reexec_with_devices():
    """Virtual host devices must be configured before jax import."""
    if "--workers" not in " ".join(sys.argv):
        return
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--workers", type=int, default=1)
    n = ap.parse_known_args()[0].workers
    if n > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
        os.execv(sys.executable, [sys.executable] + sys.argv)


_maybe_reexec_with_devices()

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402
from jax.sharding import Mesh                     # noqa: E402

from repro.checkpoint import ModelRegistry, save_checkpoint   # noqa: E402
from repro.configs import get_config              # noqa: E402
from repro.core import Compressor                 # noqa: E402
from repro.core.precision import PrecisionPolicy  # noqa: E402
from repro.data import LMDataConfig, make_lm_batches  # noqa: E402
from repro.models import build_model              # noqa: E402
from repro.optim import AdamW                     # noqa: E402
from repro.optim.schedule import cosine_warmup    # noqa: E402
from repro.train import (TrainState, make_train_step, train_loop,  # noqa: E402
                         make_bucketed_allreduce, make_sharded_train_step)
from repro.train.data_parallel import AXIS        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--workers", type=int, default=1,
                    help="data-parallel workers on virtual host devices")
    ap.add_argument("--compress", default="none",
                    choices=("none", "onebit", "dgc"),
                    help="gradient compression on the allreduce wire")
    ap.add_argument("--out", default="results/train_100m")
    args = ap.parse_args()

    # ~100M-param member of the tinyllama (llama2) family
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name="tinyllama-100m", num_layers=10, d_model=640, d_ff=2560,
        num_heads=10, num_kv_heads=2, head_dim=64, vocab_size=32000)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        batch_size=args.batch_size)
    batches = make_lm_batches(data)

    opt = AdamW(0.01)
    compressor = Compressor(args.compress, density=0.05)
    K = args.workers

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    if K > 1:
        reduce_fn = make_bucketed_allreduce(params, topology="ring",
                                            bucket_mb=4.0, order="tictac")
        step = make_train_step(
            model.loss_fn, opt, cosine_warmup(args.lr, 20, args.steps),
            precision=PrecisionPolicy(compute_dtype="float32"),
            compressor=compressor, reduce_fn=reduce_fn)
        state = TrainState.create(params, opt, compressor)
        if state["ef"] is not None:     # per-worker error-feedback state
            state["ef"] = jax.tree.map(
                lambda x: jnp.zeros((K,) + x.shape, x.dtype), state["ef"])
        if len(jax.devices()) < K:      # e.g. caller pre-set XLA_FLAGS low
            raise SystemExit(
                f"need {K} devices, have {len(jax.devices())}; unset "
                "XLA_FLAGS or set --xla_force_host_platform_device_count")
        mesh = Mesh(np.array(jax.devices()[:K]), (AXIS,))
        sharded = make_sharded_train_step(step, mesh,
                                          compressed=state["ef"] is not None)
        print(f"data-parallel: {K} workers, compress={args.compress}, "
              f"{len(reduce_fn.fused_layers)} buckets (tictac order)")

        def stacked_batch(t):
            per = [batches(t, w) for w in range(K)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

        state, hist = train_loop(sharded, state, stacked_batch,
                                 args.steps, log_every=10, jit=False)
    else:
        step = make_train_step(
            model.loss_fn, opt, cosine_warmup(args.lr, 20, args.steps),
            precision=PrecisionPolicy(compute_dtype="float32"),
            compressor=compressor)
        state = TrainState.create(params, opt, compressor)
        state, hist = train_loop(step, state, lambda t: batches(t, 0),
                                 args.steps, log_every=10)
    wall = time.time() - t0
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(hist, f, indent=1)
    ck = os.path.join(args.out, "ckpt_final")
    save_checkpoint(ck, state["params"], step=args.steps)
    reg = ModelRegistry(os.path.join(args.out, "registry"))
    reg.register("tinyllama-100m", ck, arch=cfg.name,
                 hyperparams={"lr": args.lr, "steps": args.steps},
                 metrics={"final_loss": hist[-1]["loss"]})
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {wall:.0f}s ({wall / args.steps:.2f}s/step)")


if __name__ == "__main__":
    main()
