"""End-to-end driver (deliverable b): train a ~100M-param llama-family
model for a few hundred steps on the synthetic pipeline, with cosine
schedule, checkpointing every N steps, and a final registry entry.

  PYTHONPATH=src python examples/train_100m_e2e.py --steps 300
(CPU: ~1-4 s/step at the default batch; use --steps 30 for a quick pass.)
"""
import argparse
import dataclasses
import json
import os
import time

import jax

from repro.checkpoint import ModelRegistry, save_checkpoint
from repro.configs import get_config
from repro.core.precision import PrecisionPolicy
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.schedule import cosine_warmup
from repro.train import TrainState, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--out", default="results/train_100m")
    args = ap.parse_args()

    # ~100M-param member of the tinyllama (llama2) family
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name="tinyllama-100m", num_layers=10, d_model=640, d_ff=2560,
        num_heads=10, num_kv_heads=2, head_dim=64, vocab_size=32000)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        batch_size=args.batch_size)
    batches = make_lm_batches(data)

    opt = AdamW(0.01)
    step = make_train_step(
        model.loss_fn, opt, cosine_warmup(args.lr, 20, args.steps),
        precision=PrecisionPolicy(compute_dtype="float32"))
    state = TrainState.create(params, opt)

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    state, hist = train_loop(step, state, lambda t: batches(t, 0),
                             args.steps, log_every=10)
    wall = time.time() - t0
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(hist, f, indent=1)
    ck = os.path.join(args.out, "ckpt_final")
    save_checkpoint(ck, state["params"], step=args.steps)
    reg = ModelRegistry(os.path.join(args.out, "registry"))
    reg.register("tinyllama-100m", ck, arch=cfg.name,
                 hyperparams={"lr": args.lr, "steps": args.steps},
                 metrics={"final_loss": hist[-1]["loss"]})
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {wall:.0f}s ({wall / args.steps:.2f}s/step)")


if __name__ == "__main__":
    main()
